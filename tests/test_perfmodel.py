"""Perf model: the paper's headline claims reproduce from the calibrated
constants; the simulator reproduces Fig 10's structure."""
import pytest

from repro.perfmodel.apps import cg_program, miniamr_program
from repro.perfmodel.interconnects import (CXL_SHM, CXL_SHM_NOFLUSH,
                                           ETHERNET_TCP, MELLANOX_TCP,
                                           coherence_latency)
from repro.perfmodel.simulator import Engine

KB = 1024
MiB = 1024 * 1024


class TestTable1:
    def test_raw_latency_ratios(self):
        """Observation 1: CXL (flushed) 7.2x-8.1x lower latency than
        TCP-based interconnects at 8 B."""
        r_eth = ETHERNET_TCP.raw_latency(8) / CXL_SHM.raw_latency(8)
        r_cx6 = MELLANOX_TCP.raw_latency(8) / CXL_SHM.raw_latency(8)
        assert 6.8 <= r_eth <= 8.5
        assert 7.2 <= r_cx6 <= 8.6

    def test_flush_cost_ratio(self):
        """Observation 3: cache flushing raises CXL latency ~2.8x."""
        r = CXL_SHM.raw_latency(8) / CXL_SHM_NOFLUSH.raw_latency(8)
        assert 2.5 <= r <= 3.1


class TestOMB:
    def test_onesided_latency_headlines(self):
        cxl = CXL_SHM.mpi_latency(8, onesided=True)
        assert 10e-6 <= cxl <= 15e-6            # ~12 us
        assert 44 <= ETHERNET_TCP.mpi_latency(8, onesided=True) / cxl <= 55
        assert 43 <= MELLANOX_TCP.mpi_latency(8, onesided=True) / cxl <= 54

    def test_bandwidth_headlines(self):
        bw16 = CXL_SHM.mpi_bandwidth(16 * KB, 16, onesided=True) / MiB
        assert 7700 <= bw16 <= 9600             # ~8600 MiB/s
        bw8 = CXL_SHM.mpi_bandwidth(16 * KB, 8, onesided=True) / MiB
        assert 6600 <= bw8 <= 8200              # ~7420
        # two-sided double copy: ~30% below one-sided
        two = max(CXL_SHM.mpi_bandwidth(s, 32, onesided=False)
                  for s in [2 ** k for k in range(10, 24)]) / MiB
        assert 5400 <= two <= 7200              # ~6050

    def test_crossovers(self):
        """CX-6 TCP overtakes CXL beyond 16 KB (bw) / ~256 KB (latency)."""
        sizes = [2 ** k for k in range(10, 24)]
        bw_cross = min(s for s in sizes
                       if MELLANOX_TCP.mpi_bandwidth(s, 32, onesided=True)
                       > CXL_SHM.mpi_bandwidth(s, 32, onesided=True))
        assert 16 * KB < bw_cross <= 128 * KB
        lat_cross = min(s for s in sizes
                        if MELLANOX_TCP.mpi_latency(s, onesided=True)
                        < CXL_SHM.mpi_latency(s, onesided=True))
        assert 256 * KB <= lat_cross <= 1024 * KB

    def test_eth_vs_cxl_bw_ratio(self):
        r = max(CXL_SHM.mpi_bandwidth(s, 16, onesided=True)
                / ETHERNET_TCP.mpi_bandwidth(s, 16, onesided=True)
                for s in [2 ** k for k in range(0, 24)])
        assert 55 <= r <= 90                    # paper: up to 71.6x


class TestCoherence:
    def test_uncacheable_cliff(self):
        """Fig 11: uncacheable ~256x clflush beyond 2 KB; >4000 us."""
        r = coherence_latency(2048, "uncacheable") / \
            coherence_latency(2048, "clflush")
        assert 180 <= r <= 320
        assert coherence_latency(2048, "uncacheable") > 4000e-6

    def test_clflushopt_parallelism(self):
        r = coherence_latency(128 * KB, "clflush") / \
            coherence_latency(128 * KB, "clflushopt")
        assert 3.5 <= r <= 4.5
        # single cache line: no difference
        assert coherence_latency(64, "clflush") == pytest.approx(
            coherence_latency(64, "clflushopt"))


class TestSimulator:
    def test_compute_only_scales(self):
        eng = Engine(4, CXL_SHM, procs_per_node=8)

        def prog(r):
            yield ("compute", 1.0)
        res = eng.run(prog)
        assert res["total_s"] == pytest.approx(1.0)
        assert res["comm_fraction"] == 0.0

    def test_message_rendezvous(self):
        eng = Engine(2, CXL_SHM, procs_per_node=1)

        def prog(r):
            if r == 0:
                yield ("compute", 0.5)
                yield ("send", 1, 1024, 0)
            else:
                yield ("recv", 0, 1024, 0)
        res = eng.run(prog)
        assert res["total_s"] >= 0.5            # receiver waited

    def test_fig10_structure(self):
        """CXL fastest; CG comm fraction small at small scale; miniAMR
        comm-heavy; ethernet beats CX-6 TCP at 2 nodes but not at 16+
        (latency- vs bandwidth-dominated regimes)."""
        def run(app, fab, nodes):
            n = nodes * 8
            maker = cg_program if app == "cg" else miniamr_program
            kw = {"iters": 5} if app == "cg" else {"steps": 10}
            return Engine(n, fab, procs_per_node=8).run(
                lambda r: maker(r, n, **kw))

        for nodes in (2, 8):
            cg_c = run("cg", CXL_SHM, nodes)
            cg_m = run("cg", MELLANOX_TCP, nodes)
            cg_e = run("cg", ETHERNET_TCP, nodes)
            assert cg_c["total_s"] <= cg_m["total_s"] <= cg_e["total_s"]
        assert run("cg", CXL_SHM, 2)["comm_fraction"] < 0.15

        am2_e = run("miniamr", ETHERNET_TCP, 2)
        am2_m = run("miniamr", MELLANOX_TCP, 2)
        assert am2_e["total_s"] < am2_m["total_s"]      # eth wins small
        am16_e = run("miniamr", ETHERNET_TCP, 16)
        am16_m = run("miniamr", MELLANOX_TCP, 16)
        assert am16_e["total_s"] > am16_m["total_s"]    # eth loses at scale
        assert run("miniamr", CXL_SHM, 8)["comm_fraction"] > 0.05
