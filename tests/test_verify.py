"""Cross-rank static verifier: the full compiler matrix must prove
clean, and every injected defect must be rejected with the right
diagnostic (the mutation half is what shows the checks have teeth)."""
import numpy as np
import pytest

from repro.analysis import verify as V
from repro.core import run_threads
from repro.core.sched import (MAX_ROUNDS, BufRef, RecvOp, Schedule,
                              ScheduleInvariantError, SendOp,
                              compile_schedule)


# --------------------------------------------------------------------------
# the exhaustive sweep: every shape the compilers currently emit
# --------------------------------------------------------------------------

class TestMatrixSweep:
    def test_full_matrix_is_clean(self):
        count, bad = V.sweep(16)
        assert not bad, "\n".join(str(r) for r in bad)
        # all algos x 2..16 ranks x chunk variants x hier groups
        assert count > 400

    def test_widening_boundary_config_included_and_clean(self):
        # chunk so fine the sub-rounds would blow the tag window: the
        # compiler must widen, and the widened shape must verify
        rep = V.verify_config("allreduce_rd", 16, nbytes=65536,
                              itemsize=8, chunk_bytes=64)
        assert rep.ok, str(rep)
        scheds = V.compile_group("allreduce_rd", 16, nbytes=65536,
                                 itemsize=8, chunk_bytes=64)
        assert scheds[0].rounds <= MAX_ROUNDS
        assert scheds[0].chunk_bytes > 64

    def test_report_str_mentions_config(self):
        rep = V.verify_config("bcast", 4, nbytes=128)
        assert rep.ok
        assert "bcast" in str(rep) and "OK" in str(rep)


# --------------------------------------------------------------------------
# mutation tests: inject one known defect each, expect one distinct
# diagnostic each
# --------------------------------------------------------------------------

def _two_rank(nodes0, nodes1, *, rounds, slot_sizes=None):
    """Hand-build a 2-rank schedule pair for defect injection."""
    out = []
    for rank, nodes in ((0, nodes0), (1, nodes1)):
        s = Schedule("handmade", 2, rank)
        for nd in nodes:
            s._add(nd)
        s.rounds = rounds
        if slot_sizes:
            s.slot_sizes.update(slot_sizes)
        out.append(s)
    return out


class TestMutations:
    def test_dropped_recv_is_orphan_send(self):
        scheds = V.compile_group("bcast", 2, nbytes=64)
        scheds[1].nodes = [nd for nd in scheds[1].nodes
                           if not isinstance(nd, RecvOp)]
        rep = V.verify_schedules(scheds)
        assert rep.codes() == {"orphan-send"}
        (f,) = rep.findings
        assert "no matching receive" in f.message and f.rank == 0

    def test_forward_dep_is_invariant_violation(self):
        scheds = V.compile_group("allreduce_ring", 4, nbytes=512,
                                 itemsize=8)
        scheds[0].nodes[0].deps = (2,)            # dep on a later node
        rep = V.verify_schedules(scheds)
        assert rep.codes() == {"invariant"}
        assert any("dep" in f.message for f in rep.findings)

    def test_swapped_tags_orphan_both_sides(self):
        scheds = V.compile_group("allgather_bruck", 4, nbytes=256)
        sends = [nd for nd in scheds[0].nodes if isinstance(nd, SendOp)]
        sends[0].round, sends[1].round = sends[1].round, sends[0].round
        rep = V.verify_schedules(scheds)
        # the mis-tagged sends match nothing AND starve the peers'
        # receives — both orphan classes, unlike a dropped recv
        assert "orphan-send" in rep.codes()
        assert "orphan-recv" in rep.codes()

    def test_truncated_send_is_size_mismatch(self):
        scheds = V.compile_group("allreduce_rd", 2, nbytes=256,
                                 itemsize=8)
        snd = next(nd for nd in scheds[0].nodes
                   if isinstance(nd, SendOp))
        snd.buf = BufRef(snd.buf.slot, snd.buf.off, 128)
        rep = V.verify_schedules(scheds)
        assert "size-mismatch" in rep.codes()

    def test_overlapping_unordered_writes_are_hazard(self):
        # two dependency-free receives scribble overlapping slot-0 bytes
        scheds = _two_rank(
            [RecvOp(deps=(), peer=1, buf=BufRef(0, 0, 64), round=0),
             RecvOp(deps=(), peer=1, buf=BufRef(0, 32, 64), round=1)],
            [SendOp(deps=(), peer=0, buf=BufRef(0, 0, 64), round=0),
             SendOp(deps=(0,), peer=0, buf=BufRef(0, 32, 64), round=1)],
            rounds=2)
        rep = V.verify_schedules(scheds)
        assert "buffer-hazard" in rep.codes()
        f = next(f for f in rep.findings if f.code == "buffer-hazard")
        assert f.rank == 0 and "no dependency path" in f.message

    def test_depth_overflow_against_declared_capacity(self):
        # ring posts n-1 receives toward the left neighbour; a declared
        # capacity of 1 cannot hold them
        scheds = V.compile_group("allreduce_ring", 4, nbytes=512,
                                 itemsize=8)
        rep = V.verify_schedules(scheds, matchbox_capacity=1)
        assert rep.codes() == {"depth-overflow"}
        assert any("capacity" in f.message for f in rep.findings)

    def test_cross_rank_cycle_is_deadlock(self):
        # rank 0 sends only after receiving, rank 1 likewise, and the
        # wire edges close the loop: a classic exchange deadlock
        scheds = _two_rank(
            [RecvOp(deps=(), peer=1, buf=BufRef(1, 0, 64), round=0),
             SendOp(deps=(0,), peer=1, buf=BufRef(0, 0, 64), round=1)],
            [RecvOp(deps=(), peer=0, buf=BufRef(1, 0, 64), round=1),
             SendOp(deps=(0,), peer=0, buf=BufRef(0, 0, 64), round=0)],
            rounds=2)
        rep = V.verify_schedules(scheds)
        assert "deadlock" in rep.codes()
        f = next(f for f in rep.findings if f.code == "deadlock")
        assert "cycle" in f.message and "->" in f.message

    def test_unchained_same_slot_sends_are_flagged(self):
        scheds = _two_rank(
            [SendOp(deps=(), peer=1, buf=BufRef(0, 0, 64), round=0),
             SendOp(deps=(), peer=1, buf=BufRef(0, 64, 64), round=1)],
            [RecvOp(deps=(), peer=0, buf=BufRef(1, 0, 64), round=0),
             RecvOp(deps=(), peer=0, buf=BufRef(2, 0, 64), round=1)],
            rounds=2)
        rep = V.verify_schedules(scheds)
        assert "unchained-send" in rep.codes()
        assert any("drain-ack" in f.message for f in rep.findings)

    def test_zero_byte_sends_exempt_from_chaining(self):
        # the dissemination barrier's empty messages never take the
        # pool path — they must NOT trip the send-chain rule
        rep = V.verify_config("barrier", 8)
        assert rep.ok, str(rep)

    def test_duplicate_round_is_duplicate_match(self):
        scheds = _two_rank(
            [SendOp(deps=(), peer=1, buf=BufRef(0, 0, 64), round=0),
             SendOp(deps=(0,), peer=1, buf=BufRef(0, 0, 64), round=0)],
            [RecvOp(deps=(), peer=0, buf=BufRef(1, 0, 64), round=0)],
            rounds=1)
        rep = V.verify_schedules(scheds)
        assert "duplicate-match" in rep.codes()

    def test_tag_window_overflow_flagged(self):
        scheds = V.compile_group("bcast", 2, nbytes=64)
        for s in scheds:
            s.rounds = MAX_ROUNDS + 1
        rep = V.verify_schedules(scheds)
        assert "tag-window" in rep.codes()

    def test_rounds_disagreement_flagged(self):
        scheds = V.compile_group("bcast", 2, nbytes=64)
        scheds[1].rounds += 1
        rep = V.verify_schedules(scheds)
        assert "rounds-mismatch" in rep.codes()

    def test_raise_if_failed_carries_diagnostics(self):
        scheds = V.compile_group("bcast", 2, nbytes=64)
        scheds[1].nodes = []
        with pytest.raises(ScheduleInvariantError, match="orphan-send"):
            V.verify_schedules(scheds).raise_if_failed()


# --------------------------------------------------------------------------
# satellite: ScheduleInvariantError replaces bare asserts
# --------------------------------------------------------------------------

class TestInvariantError:
    def test_validate_raises_typed_error_with_context(self):
        s = Schedule("t", 2, 0)
        s._add(SendOp(deps=(), peer=1, buf=BufRef(0, 0, 8), round=0))
        s.rounds = 1
        s.nodes[0].deps = (5,)
        with pytest.raises(ScheduleInvariantError) as ei:
            s.validate()
        assert ei.value.node == 0 and ei.value.deps == (5,)
        assert "t" in str(ei.value) and "rank=0" in str(ei.value)

    def test_round_outside_span_raises(self):
        s = Schedule("t", 2, 0)
        s._add(RecvOp(deps=(), peer=1, buf=BufRef(0, 0, 8), round=3))
        s.rounds = 1
        with pytest.raises(ScheduleInvariantError, match="outside"):
            s.validate()

    def test_compiler_preconditions_survive_without_asserts(self):
        with pytest.raises(ValueError, match="power-of-two"):
            V.compile_group("allreduce_rd", 6, nbytes=64, itemsize=8)
        with pytest.raises(ValueError, match="divide"):
            V.compile_group("allreduce_hier", 8, nbytes=64, itemsize=8,
                            group=3)


# --------------------------------------------------------------------------
# satellite: matchbox demand has one source of truth
# --------------------------------------------------------------------------

class TestMatchboxDepth:
    @pytest.mark.parametrize("kind,kw", [
        ("allreduce_rd", dict(n=8, nbytes=512, itemsize=8)),
        ("allreduce_ring", dict(n=6, nbytes=480, itemsize=8)),
        ("allreduce_hier", dict(n=8, nbytes=512, itemsize=8, group=2)),
        ("allgather_bruck", dict(n=7, nbytes=128)),
        ("bcast", dict(n=8, nbytes=512)),
    ])
    def test_declared_depth_matches_recount(self, kind, kw):
        n = kw.pop("n")
        for sched in V.compile_group(kind, n, **kw):
            per = {}
            for nd in sched.recv_nodes():
                per[nd.peer] = per.get(nd.peer, 0) + 1
            for peer, depth in per.items():
                assert sched.required_matchbox_depth(peer) == depth
            assert sched.required_matchbox_depth() == \
                max(per.values(), default=0)
            # the legacy name must stay an alias, not a second formula
            assert sched.max_recvs_per_peer() == \
                sched.required_matchbox_depth()

    def test_persistent_demand_derived_from_schedule(self):
        def prog(env):
            x = np.ones(64)
            req = env.comm.allreduce_init(x, algo="ring")
            demand = req.matchbox_demand
            declared = 2 * req._sched.required_matchbox_depth()
            recount = {}
            for nd in req._sched.recv_nodes():
                recount[nd.peer] = recount.get(nd.peer, 0) + 1
            req.free()
            return demand, declared, max(recount.values())

        for demand, declared, worst in run_threads(4, prog):
            assert demand == declared == 2 * worst


# --------------------------------------------------------------------------
# the compile_schedule(..., verify=True) debug hook
# --------------------------------------------------------------------------

class TestVerifyHook:
    def test_hook_accepts_clean_config(self):
        view = V._CompileView(4, 1)
        sched = compile_schedule(view, "allreduce_ring", 512, 8,
                                 chunk_bytes=128, verify=True)
        assert sched.rounds <= MAX_ROUNDS

    def test_hook_rejects_bad_config(self, monkeypatch):
        # simulate a compiler regression: the hook must surface the
        # verifier's findings as ScheduleInvariantError
        bad = V.VerificationReport(
            "stub", [V.Finding("deadlock", "injected")])
        monkeypatch.setattr(V, "verify_config",
                            lambda *a, **k: bad)
        with pytest.raises(ScheduleInvariantError, match="deadlock"):
            compile_schedule(V._CompileView(2, 0), "bcast", 64,
                             verify=True)

    def test_cli_sweep_entrypoint(self, capsys):
        assert V.main(["--max-n", "4"]) == 0
        assert "0 failing" in capsys.readouterr().out
