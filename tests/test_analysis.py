"""HLO analyzer unit tests on synthetic HLO text: trip-count scaling,
collective wire math, dot FLOPs via the symbol table, DUS accounting."""
from repro.analysis import hlo as H


def test_shape_bytes():
    assert H.shape_bytes("f32[2,3]{1,0}") == 24
    assert H.shape_bytes("bf16[4,4]") == 32
    assert H.shape_bytes("(f32[4], s32[2])") == 24
    assert H.shape_bytes("pred[]") == 1
    assert H.shape_bytes("token[]") == 0


def test_wire_math():
    # ring factors per kind
    assert H._wire_bytes("all-reduce", 100, 4) == 2 * 3 / 4 * 100
    assert H._wire_bytes("all-gather", 100, 4) == 3 / 4 * 100
    assert H._wire_bytes("reduce-scatter", 25, 4) == 3 * 25
    assert H._wire_bytes("all-to-all", 100, 4) == 3 / 4 * 100
    assert H._wire_bytes("collective-permute", 100, 2) == 100
    assert H._wire_bytes("all-reduce", 100, 1) == 0.0


SYNTH = """
HloModule synth

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %dot.1 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%dot.1), replica_groups=[4,4]<=[16], to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %lim = s32[] constant(10)
  ROOT %c = pred[] compare(%i, %lim), direction=LT
}

ENTRY %main (x0: f32[8,16]) -> f32[8,16] {
  %x0 = f32[8,16]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%zero, %x0)
  %w = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%w), index=1
}
"""


def test_while_trip_count_scaling():
    st = H.analyze_module(SYNTH)
    # dot: 2 * (8*16) * 16 = 4096 flops, x10 trips
    assert st.flops == 4096 * 10
    # all-reduce: f32[8,16] = 512 B, group 4 -> 2*(3/4)*512 = 768 B, x10
    assert abs(st.total_wire_bytes - 768 * 10) < 1e-6
    assert st.coll_counts["all-reduce"] == 10
    assert st.unparsed_while == 0


DUS_SYNTH = """
HloModule dus

%fused_dus (p0: f32[10,64], p1: f32[1,64], p2: s32[]) -> f32[10,64] {
  %p0 = f32[10,64]{1,0} parameter(0)
  %p1 = f32[1,64]{1,0} parameter(1)
  %p2 = s32[] parameter(2)
  %z = s32[] constant(0)
  ROOT %d = f32[10,64]{1,0} dynamic-update-slice(%p0, %p1, %p2, %z)
}

ENTRY %main (buf: f32[10,64], upd: f32[1,64], i: s32[]) -> f32[10,64] {
  %buf = f32[10,64]{1,0} parameter(0)
  %upd = f32[1,64]{1,0} parameter(1)
  %i = s32[] parameter(2)
  ROOT %f = f32[10,64]{1,0} fusion(%buf, %upd, %i), kind=kLoop, calls=%fused_dus
}
"""


def test_dus_fusion_charged_at_slice_size():
    st = H.analyze_module(DUS_SYNTH)
    # a naive count would be operands+output = 2820 + 2560 = 5380 B; the
    # aliased DUS charges 2x the update slice (512) + the non-aliased
    # operands (upd 256 + idx 4) = 772 B
    assert st.bytes_ == 772.0, st.bytes_


def test_roofline_terms():
    r = H.Roofline(flops_per_device=197e12, bytes_per_device=819e9,
                   wire_bytes_per_device=0.0,
                   model_flops_per_device=98.5e12)
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 1.0) < 1e-9
    assert r.bottleneck in ("compute", "memory")
    assert abs(r.useful_flops_ratio - 0.5) < 1e-9
    assert abs(r.roofline_fraction - 0.5) < 1e-9


def test_model_flops_kinds():
    from repro.configs import SHAPES, get_config
    cfg = get_config("llama3-8b")
    tr = H.model_flops(cfg, SHAPES["train_4k"], 256)
    pf = H.model_flops(cfg, SHAPES["prefill_32k"], 256)
    dc = H.model_flops(cfg, SHAPES["decode_32k"], 256)
    n = cfg.param_counts()["active"]
    assert abs(tr - 6 * n * 256 * 4096 / 256) / tr < 1e-9
    assert abs(pf - 2 * n * 32 * 32768 / 256) / pf < 1e-9
    assert abs(dc - 2 * n * 128 / 256) / dc < 1e-9
