"""Receiver-posted rendezvous (the matchbox): one-copy delivery into
pre-posted pool-resident / registered destinations, the claim/retract
race protocol, salvage of mis-claimed payloads, FIFO matching under
mixed eager/staged/posted traffic, collective teardown, and the CI
copied-bytes budget gate helper."""
import numpy as np
import pytest

from repro.core import Registration, run_threads
from repro.core.runtime import run_processes

CELL = 4096


# --------------------------------------------------------------------------
# the one-copy path
# --------------------------------------------------------------------------

class TestPostedDelivery:
    def test_posted_hit_poolbuffer_dest(self):
        """Receiver posts a PoolBuffer destination before the sender
        moves: the payload lands with ONE protocol copy (sender-side
        write), zero receiver-side drain."""
        size = 8 * CELL

        def prog(env):
            st = env.arena.view.stats
            if env.rank == 0:
                env.comm.recv(1, tag=2)          # credit: entry is live
                s0 = st.snapshot()
                env.comm.send(1, b"\xab" * size, tag=1)
                d = st.delta(s0)
                return (env.comm.posted_sends,
                        d["path_copied_bytes"].get("rndv_posted", 0))
            pb = env.comm.alloc_buffer(size)
            rreq = env.comm.irecv_into(0, pb, tag=1)   # posts the entry
            env.comm.send(0, b"", tag=2)
            s0 = st.snapshot()
            rreq.wait(30)
            recv_copied = st.delta(s0)["copied_bytes"]
            assert rreq.nbytes == size
            assert pb.read(0, 8) == b"\xab" * 8
            return recv_copied

        res = run_threads(2, prog, cell_size=CELL, eager_threshold=0,
                          pool_bytes=32 << 20, timeout=60)
        hits, sender_posted = res[0]
        assert hits == 1
        assert sender_posted == size             # the one payload copy
        # receiver side touched only the 40B descriptor cell, no payload
        assert res[1] < 256

    def test_posted_vs_staged_copy_ratio(self):
        """The acceptance bar at test scale: posted rendezvous moves
        >= 1.9x fewer protocol-counted bytes than the staged path."""
        size = 256 * 1024
        iters = 3

        def make_prog(posted):
            def prog(env):
                st = env.arena.view.stats
                if env.rank == 0:
                    src = b"\xee" * size
                    env.comm.barrier()
                    s0 = st.snapshot()
                    for _ in range(iters):
                        env.comm.recv(1, tag=2)
                        env.comm.send(1, src, tag=1)
                    return st.delta(s0)["copied_bytes"]
                dst = env.comm.alloc_buffer(size) if posted \
                    else bytearray(size)
                env.comm.barrier()
                s0 = st.snapshot()
                for _ in range(iters):
                    rreq = env.comm.irecv_into(0, dst, tag=1)
                    env.comm.send(0, b"", tag=2)
                    rreq.wait(30)
                return st.delta(s0)["copied_bytes"]
            return prog

        staged = sum(run_threads(2, make_prog(False), cell_size=CELL,
                                 eager_threshold=0, pool_bytes=64 << 20,
                                 timeout=120)) / iters
        posted = sum(run_threads(2, make_prog(True), cell_size=CELL,
                                 eager_threshold=0, pool_bytes=64 << 20,
                                 timeout=120)) / iters
        assert staged / posted >= 1.9

    def test_registration_roundtrip(self):
        """A registered user buffer: sender fills the shadow, completion
        drains shadow -> user exactly once; the pin is reusable and
        freeable."""
        size = 5 * CELL

        def prog(env):
            peer = 1 - env.rank
            user = bytearray(size)
            reg = env.comm.register(user)
            assert isinstance(reg, Registration)
            for i in range(3):
                rreq = env.comm.irecv_into(peer, reg, tag=4)
                env.comm.barrier()               # both entries posted
                env.comm.send(peer, bytes([i]) * size, tag=4)
                rreq.wait(30)
                assert user[0] == i and user[-1] == i
                env.comm.barrier()
            posted = env.comm.posted_sends
            before = env.arena.stats()["slots_used"]
            reg.free()
            reg.free()                           # idempotent
            return posted, before - env.arena.stats()["slots_used"]

        for posted, released in run_threads(
                2, prog, cell_size=CELL, eager_threshold=0,
                pool_bytes=32 << 20, timeout=120):
            assert posted == 3                   # every send hit the entry
            assert released == 1

    def test_fallback_when_sender_moves_first(self):
        """No entry posted when the descriptor is enqueued -> the wire
        falls back to the staged path; a later pool-resident receive
        still drains it correctly (wire compatibility)."""
        size = 6 * CELL

        def prog(env):
            if env.rank == 0:
                env.comm.send(1, b"\xcd" * size, tag=1)  # before any post
                env.comm.send(1, b"", tag=2)
                return env.comm.posted_sends
            env.comm.recv(0, tag=2)              # send already completed
            pb = env.comm.alloc_buffer(size)
            n, _ = env.comm.recv_into(0, pb, tag=1)
            assert n == size and pb.read(0, 2) == b"\xcd\xcd"
            return None

        res = run_threads(2, prog, cell_size=CELL, eager_threshold=0,
                          pool_bytes=32 << 20, timeout=60)
        assert res[0] == 0

    def test_posted_works_across_processes(self):
        """The matchbox protocol over REAL shared memory (the paper's
        measurement configuration)."""
        size = 128 * 1024

        def prog(env):
            if env.rank == 0:
                env.comm.recv(1, tag=2)
                env.comm.send(1, b"\x5a" * size, tag=1)
                return env.comm.posted_sends
            pb = env.comm.alloc_buffer(size)
            rreq = env.comm.irecv_into(0, pb, tag=1)
            env.comm.send(0, b"", tag=2)
            rreq.wait(30)
            assert pb.read(0, 4) == b"\x5a" * 4
            return rreq.nbytes

        res = run_processes(2, prog, pool_bytes=64 << 20,
                            eager_threshold=0, timeout=120)
        assert res[0] == 1 and res[1] == size


# --------------------------------------------------------------------------
# retract / salvage races
# --------------------------------------------------------------------------

class TestRetractAndSalvage:
    def test_entry_retracted_after_eager_completion(self):
        """A posted entry whose receive completes via the EAGER path is
        withdrawn — a later large send must not scribble the completed
        buffer, and the pair stays usable."""
        def prog(env):
            if env.rank == 0:
                env.comm.recv(1, tag=9)                  # entry posted
                env.comm.send(1, b"tiny", tag=1)         # eager -> retract
                env.comm.recv(1, tag=9)
                env.comm.send(1, b"\xbb" * (8 * CELL), tag=1)
                return env.comm.posted_sends
            pb = env.comm.alloc_buffer(8 * CELL)
            rreq = env.comm.irecv_into(0, pb, tag=1)     # posts entry
            env.comm.send(0, b"", tag=9)
            rreq.wait(30)
            assert rreq.nbytes == 4
            assert pb.read(0, 4) == b"tiny"
            assert not env.comm._mb_records               # retracted
            frozen = pb.read(0, 4)
            # second message goes to a FRESH posting of a new receive
            pb2 = env.comm.alloc_buffer(8 * CELL)
            rreq2 = env.comm.irecv_into(0, pb2, tag=1)
            env.comm.send(0, b"", tag=9)
            rreq2.wait(30)
            assert pb2.read(0, 2) == b"\xbb\xbb"
            assert pb.read(0, 4) == frozen                # untouched
            return None

        res = run_threads(2, prog, cell_size=CELL,
                          eager_threshold=CELL, pool_bytes=32 << 20,
                          timeout=60)
        assert res[0] == 1                    # only the second send hit

    def test_foreign_claim_salvaged_in_order(self):
        """MPI matching order beats the sender's entry guess: an older
        bytes-mode ANY_TAG receive wins the message even though the
        sender delivered it into a younger posted buffer; the posted
        receive then gets the NEXT message in place."""
        size = 6 * CELL

        def prog(env):
            if env.rank == 0:
                env.comm.recv(1, tag=9)
                env.comm.send(1, b"\x11" * size, tag=5)   # claims entry
                env.comm.recv(1, tag=9)            # salvage + re-post done
                env.comm.send(1, b"\x22" * size, tag=5)
                return env.comm.posted_sends
            from repro.core.pt2pt import ANY_TAG
            r_plain = env.comm.irecv(0, ANY_TAG)   # posted FIRST, no entry
            pb = env.comm.alloc_buffer(size)
            r_posted = env.comm.irecv_into(0, pb, tag=5)  # posts entry
            env.comm.send(0, b"", tag=9)
            a = r_plain.wait(30)                   # salvage path
            env.comm.send(0, b"", tag=9)
            r_posted.wait(30)
            assert a == b"\x11" * size             # FIFO order preserved
            assert pb.read(0, 2) == b"\x22\x22"    # next message, in place
            return None

        res = run_threads(2, prog, cell_size=CELL, eager_threshold=0,
                          pool_bytes=32 << 20, timeout=60)
        # both sends found a live entry (the second via the re-post)
        assert res[0] == 2

    def test_capacity_miss_falls_back_and_truncates(self):
        """A message larger than the posted capacity never claims the
        entry; the staged fallback raises MPI_ERR_TRUNCATE semantics and
        the communicator stays usable."""
        def prog(env):
            if env.rank == 0:
                env.comm.recv(1, tag=9)
                env.comm.send(1, b"\xcc" * (4 * CELL), tag=1)
                env.comm.send(1, b"ok", tag=2)
                return env.comm.posted_sends
            pb = env.comm.alloc_buffer(CELL)          # too small
            rreq = env.comm.irecv_into(0, pb, tag=1)
            env.comm.send(0, b"", tag=9)
            with pytest.raises(ValueError, match="exceeds"):
                rreq.wait(30)
            data, _ = env.comm.recv(0, tag=2)
            assert data == b"ok"
            assert not env.comm._mb_records
            return None

        res = run_threads(2, prog, cell_size=CELL, eager_threshold=0,
                          pool_bytes=32 << 20, timeout=60)
        assert res[0] == 0


# --------------------------------------------------------------------------
# persistent receives pre-post
# --------------------------------------------------------------------------

class TestPersistentPrePost:
    def test_recv_init_preposts_and_stays_flat(self):
        """recv_init registers the user buffer ONCE; every start()
        re-arms the same shadow-backed entry, every iteration's send
        hits it, and the arena slot count stays flat."""
        iters = 5
        nelem = 3 * CELL            # bytes > threshold: rendezvous

        def prog(env):
            peer = 1 - env.rank
            sbuf = np.zeros(nelem, np.uint8)
            rbuf = np.zeros(nelem, np.uint8)
            ps = env.comm.send_init(peer, sbuf, tag=7)
            pr = env.comm.recv_init(peer, rbuf, tag=7)
            slots = []
            for i in range(iters):
                sbuf[:] = i + 1
                pr.start()
                env.comm.barrier()          # all entries posted first
                ps.start()
                n = pr.wait(30)
                ps.wait(30)
                assert n == nelem and rbuf[0] == i + 1
                env.comm.barrier()
                slots.append(env.arena.stats()["slots_used"])
            env.comm.barrier()      # all ranks done measuring
            posted = env.comm.posted_sends
            ps.free()
            pr.free()
            return posted, slots

        for posted, slots in run_threads(
                2, prog, cell_size=CELL, eager_threshold=CELL,
                pool_bytes=32 << 20, timeout=120):
            assert posted == iters          # deterministic hits
            assert len(set(slots)) == 1     # flat footprint

    def test_recv_init_poolbuffer_dest(self):
        def prog(env):
            if env.rank == 0:
                pb = env.comm.alloc_buffer(4 * CELL)
                pr = env.comm.recv_init(1, pb, tag=3)
                out = []
                for _ in range(2):
                    pr.start()
                    env.comm.send(1, b"", tag=9)      # entry is live
                    pr.wait(30)
                    out.append(pb.read(0, 1))
                return out
            for i in range(2):
                env.comm.recv(0, tag=9)
                env.comm.send(0, bytes([i + 7]) * (4 * CELL), tag=3)
            return env.comm.posted_sends

        res = run_threads(2, prog, cell_size=CELL, eager_threshold=0,
                          pool_bytes=32 << 20, timeout=60)
        assert res[0] == [b"\x07", b"\x08"]
        assert res[1] == 2


# --------------------------------------------------------------------------
# FIFO matching under interleaved eager / staged / posted traffic
# --------------------------------------------------------------------------

class TestInterleaveStress:
    def test_mixed_paths_fifo_any_tag_full_queues(self):
        """Full-duplex stress: both ranks stream 45 messages at each
        other through deliberately TINY queues (n_cells=2) while the
        receiver rotates bytes-mode, plain-buffer and posted
        destinations, all ANY_TAG. Per-source FIFO must hold exactly
        (payload sequence numbers arrive in order), no deadlock, and
        every data-plane path must actually fire."""
        n_msgs = 45
        big = 3 * CELL

        def prog(env):
            from repro.core.pt2pt import ANY_TAG
            peer = 1 - env.rank
            rng = np.random.default_rng(17 + env.rank)
            sizes = [int(rng.choice([64, CELL - 64, big]))
                     for _ in range(n_msgs)]
            # fire-and-forget the whole stream: queues (2 cells) fill
            # immediately, so completion relies on the progress engine
            sreqs = [env.comm.isend(
                peer, i.to_bytes(4, "little") * (sizes[i] // 4),
                tag=i % 7) for i in range(n_msgs)]
            pb = env.comm.alloc_buffer(big)
            got = []
            for i in range(n_msgs):
                kind = i % 3
                if kind == 0:                        # bytes-mode
                    data, _ = env.comm.recv(peer, ANY_TAG, timeout=60)
                    got.append(data[:4])
                elif kind == 1:                      # plain buffer
                    buf = bytearray(big)
                    n, _ = env.comm.recv_into(peer, buf, ANY_TAG,
                                              timeout=60)
                    got.append(bytes(buf[:4]))
                else:                                # posted-capable
                    n, _ = env.comm.recv_into(peer, pb, ANY_TAG,
                                              timeout=60)
                    got.append(pb.read(0, 4))
            env.comm.waitall(sreqs, timeout=60)
            order = [int.from_bytes(g, "little") for g in got]
            assert order == list(range(n_msgs)), order     # strict FIFO
            return (env.comm.eager_sends, env.comm.rndv_sends,
                    env.comm.posted_sends)

        res = run_threads(2, prog, cell_size=CELL, n_cells=2,
                          eager_threshold=CELL, pool_bytes=64 << 20,
                          timeout=300)
        for eager, rndv, posted in res:
            assert eager > 0 and rndv > 0
        # posted hits are timing-dependent here; the paths must coexist
        # without corrupting FIFO order either way
        assert all(r[0] + r[1] == n_msgs for r in res)


# --------------------------------------------------------------------------
# collective teardown (Comm.free bugfix)
# --------------------------------------------------------------------------

class TestCommFree:
    def test_free_releases_queue_matrix_and_matchbox(self):
        """free() is collective, releases the comm's arena objects
        (queue matrix, barrier, matchbox, publication flag) and round
        buffers on every rank, and is idempotent."""
        def prog(env):
            sub = env.comm.split(0, key=env.rank)
            x = np.arange(3 * CELL, dtype=np.float64)
            sub.allreduce(x, algo="ring")            # round buffers live
            name = sub.name
            before = env.arena.stats()["slots_used"]
            sub.free()
            sub.free()                               # idempotent
            env.comm.barrier()
            gone = []
            for suffix in (":mq", ":bar", ":mb", ":ok"):
                try:
                    env.arena.open(name + suffix)
                    gone.append(False)
                except FileNotFoundError:
                    gone.append(True)
            released = before - env.arena.stats()["slots_used"]
            # world must remain fully functional
            y = env.comm.allreduce(np.ones(8), algo="ring")
            assert np.allclose(y, 2.0)
            return gone, released

        res = run_threads(2, prog, cell_size=CELL, pool_bytes=64 << 20,
                          timeout=120)
        for gone, released in res:
            assert all(gone), gone
            assert released > 0                      # round buffers went

    def test_free_reclaims_trailing_stagers(self):
        """A staged send completes at descriptor enqueue, before the
        receiver's ack; with no further pt2pt ops the acked stager waits
        for a progress sweep that never comes. free() must reclaim it
        instead of leaking one rv:* object per comm lifecycle."""
        def prog(env):
            sub = env.comm.dup()
            baseline = env.arena.stats()["slots_used"]
            if env.rank == 0:
                sub.send(1, b"\x71" * (4 * CELL), tag=1)  # staged, trailing
            else:
                dst = bytearray(4 * CELL)
                sub.recv_into(0, dst, tag=1)
                assert dst[0] == 0x71
            env.comm.barrier()
            assert sub._stagers or env.rank != 0   # leak candidate exists
            sub.free()
            env.comm.barrier()
            # everything sub created (incl. the stager) is gone
            return env.arena.stats()["slots_used"] <= baseline

        assert all(run_threads(2, prog, cell_size=CELL,
                               eager_threshold=0, pool_bytes=64 << 20,
                               timeout=120))

    def test_free_with_live_postings(self):
        """free() retracts live matchbox postings (e.g. an abandoned
        irecv_into) instead of leaving claimable entries behind."""
        def prog(env):
            sub = env.comm.dup()
            if env.rank == 0:
                pb = sub.alloc_buffer(4 * CELL)
                sub.irecv_into(1, pb, tag=1)         # posted, never waited
                assert sub._mb_records
            env.comm.barrier()
            sub.free()
            assert not sub._mb_records
            return True

        assert all(run_threads(2, prog, cell_size=CELL,
                               pool_bytes=64 << 20, timeout=120))


# --------------------------------------------------------------------------
# CI copied-bytes budget gate (pure helper)
# --------------------------------------------------------------------------

class TestBudgetGate:
    BUDGET = {"pt2pt_rndv_posted@1MiB": 1_048_704.0,
              "pt2pt_rndv_staged@1MiB": 2_098_129.0}

    def test_within_tolerance_passes(self):
        from benchmarks.fig5_8_osu import check_budget
        measured = {k: v * 1.05 for k, v in self.BUDGET.items()}
        assert check_budget(measured, self.BUDGET, tol=0.10) == []

    def test_injected_extra_copy_fails(self):
        """An extra payload copy on the posted path (~2x) must trip the
        gate — the regression the CI bench-gate job exists to catch."""
        from benchmarks.fig5_8_osu import check_budget
        measured = dict(self.BUDGET)
        measured["pt2pt_rndv_posted@1MiB"] *= 2.0    # injected copy
        problems = check_budget(measured, self.BUDGET, tol=0.10)
        assert any("REGRESSION" in p and "rndv_posted" in p
                   for p in problems)

    def test_improvement_beyond_tolerance_demands_refresh(self):
        from benchmarks.fig5_8_osu import check_budget
        measured = dict(self.BUDGET)
        measured["pt2pt_rndv_staged@1MiB"] *= 0.5
        problems = check_budget(measured, self.BUDGET, tol=0.10)
        assert any("STALE BUDGET" in p for p in problems)

    def test_missing_and_unbudgeted_keys_flagged(self):
        from benchmarks.fig5_8_osu import check_budget
        problems = check_budget({"new_path@1MiB": 1.0}, self.BUDGET)
        assert any(p.startswith("MISSING") for p in problems)
        assert any(p.startswith("UNBUDGETED") for p in problems)
