"""Training substrate: optimizer math, data determinism, checkpointing
(fs + arena), fault tolerance (restart bitwise-identity, failure
injection, elastic width change, straggler detection), loss-decreases
integration."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.core import Arena, LocalPool
from repro.launch.train import run_training
from repro.models import lm
from repro.train import data as D
from repro.train import optimizer as opt
from repro.train.checkpoint import ArenaCheckpoint, CheckpointManager
from repro.train.fault import (FailureInjector, HeartbeatBoard,
                               InjectedFailure, ElasticPlan)


def tiny(arch="smollm-135m", seq=32, batch=4):
    cfg = get_config(arch).reduced()
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=seq,
                                global_batch=batch)
    return cfg, shape


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------

class TestOptimizer:
    def test_adamw_matches_reference(self):
        oc = opt.OptConfig(name="adamw", lr=1e-2, warmup_steps=1,
                           weight_decay=0.0, grad_clip=1e9)
        p = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
        g = {"w": jnp.full((4, 4), 0.5), "b": jnp.ones((4,))}
        st = opt.init(oc, p)
        p1, st1, m = opt.apply_updates(oc, p, g, st)
        # step 1 reference: mhat = g, vhat = g^2 -> update = g/(|g|+eps)
        lr = float(opt.lr_at(oc, jnp.zeros((), jnp.int32)))
        exp_w = 1.0 - lr * (0.5 / (0.5 + oc.eps))
        np.testing.assert_allclose(np.asarray(p1["w"]), exp_w, rtol=1e-5)
        assert int(st1["count"]) == 1

    def test_grad_clip(self):
        g = {"a": jnp.full((100,), 10.0)}
        clipped, gn = opt.clip_by_global_norm(g, 1.0)
        assert float(gn) == pytest.approx(100.0)
        assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(
            1.0, rel=1e-5)

    def test_adafactor_factored_shapes(self):
        oc = opt.OptConfig(name="adafactor", factored_dims_min=4)
        p = {"w": jnp.ones((8, 16)), "b": jnp.zeros((8,))}
        st = opt.init(oc, p)
        assert st["vr"]["w"].shape == (8,)
        assert st["vc"]["w"].shape == (16,)
        assert st["vc"]["b"].shape == (8,)     # unfactored
        g = jax.tree.map(lambda x: jnp.ones_like(x) * 0.1, p)
        p1, st1, _ = opt.apply_updates(oc, p, g, st)
        assert np.all(np.isfinite(np.asarray(p1["w"])))
        assert float(jnp.abs(p1["w"] - p["w"]).max()) > 0

    def test_lr_schedule(self):
        oc = opt.OptConfig(lr=1.0, warmup_steps=10, decay_steps=100,
                           min_lr_ratio=0.1)
        lrs = [float(opt.lr_at(oc, jnp.asarray(s))) for s in
               (0, 9, 10, 100, 1000)]
        assert lrs[0] < lrs[1] <= lrs[2]        # warmup
        assert lrs[3] == pytest.approx(0.1, rel=1e-3)
        assert lrs[4] == pytest.approx(0.1, rel=1e-3)


# --------------------------------------------------------------------------
# data
# --------------------------------------------------------------------------

class TestData:
    def test_deterministic_by_step(self):
        cfg, shape = tiny()
        dc = D.for_model(cfg, shape)
        ds = D.SyntheticLM(dc)
        a = ds.batch(5)
        b = ds.batch(5)
        assert np.array_equal(a["tokens"], b["tokens"])
        c = ds.batch(6)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_sharding_partitions_batch(self):
        cfg, shape = tiny(batch=8)
        ds = D.SyntheticLM(D.for_model(cfg, shape))
        sh0 = ds.batch(0, 0, 2)
        sh1 = ds.batch(0, 1, 2)
        assert sh0["tokens"].shape[0] == 4
        assert not np.array_equal(sh0["tokens"], sh1["tokens"])

    def test_prefetcher(self):
        cfg, shape = tiny()
        ds = D.SyntheticLM(D.for_model(cfg, shape))
        pf = D.Prefetcher(ds, start_step=3)
        s, b = pf.next()
        assert s == 3 and "tokens" in b
        s, _ = pf.next()
        assert s == 4
        pf.stop()

    def test_markov_structure_learnable(self):
        """The synthetic stream must have sub-uniform entropy (something
        to learn)."""
        cfg, shape = tiny(seq=256, batch=8)
        ds = D.SyntheticLM(D.for_model(cfg, shape))
        t = ds.batch(0)["tokens"]
        # bigram predictability: most-frequent successor share >> 1/V
        pairs = {}
        for row in t:
            for a, b in zip(row[:-1], row[1:]):
                pairs.setdefault(int(a), {}).setdefault(int(b), 0)
                pairs[int(a)][int(b)] += 1
        top_share = np.mean([max(v.values()) / sum(v.values())
                             for v in pairs.values() if sum(v.values()) > 5])
        assert top_share > 3.0 / cfg.vocab_size


# --------------------------------------------------------------------------
# checkpoint + fault tolerance
# --------------------------------------------------------------------------

class TestCheckpoint:
    def test_fs_roundtrip_bitwise(self, tmp_path):
        cfg, _ = tiny()
        params = lm.init(cfg, jax.random.key(0))
        mgr = CheckpointManager(tmp_path)
        mgr.save(7, params)
        step, restored = mgr.restore(params)
        assert step == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_async_save_and_latest(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        tree = {"x": jnp.arange(10)}
        mgr.save_async(1, tree)
        mgr.save_async(2, {"x": jnp.arange(10) * 2})
        mgr.wait()
        assert mgr.latest_step() == 2
        _, got = mgr.restore(tree)
        assert np.array_equal(np.asarray(got["x"]), np.arange(10) * 2)

    def test_arena_backend(self):
        arena = Arena(LocalPool(16 << 20), 0, initialize=True)
        ck = ArenaCheckpoint(arena, "t")
        tree = {"w": jnp.asarray(np.random.default_rng(0).normal(
            size=(32, 8)).astype(np.float32)),
            "s": jnp.asarray(3, jnp.int32)}
        ck.save(11, tree)
        step, got = ck.restore(tree)
        assert step == 11
        assert np.array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
        ck.save(12, tree)        # overwrite path (destroy + recreate)
        step, _ = ck.restore(tree)
        assert step == 12


class TestFaultTolerance:
    def test_restart_bitwise_identical(self, tmp_path):
        cfg, shape = tiny()
        # uninterrupted run
        ref = run_training(cfg, shape, 8, quiet=True)
        # interrupted at step 5, then resumed
        inj = FailureInjector(fail_at_step=5)
        with pytest.raises(InjectedFailure):
            run_training(cfg, shape, 8, ckpt_dir=tmp_path / "c",
                         ckpt_every=2, injector=inj, quiet=True)
        out = run_training(cfg, shape, 8, ckpt_dir=tmp_path / "c",
                           ckpt_every=2, quiet=True)
        for a, b in zip(jax.tree.leaves(ref["params"]),
                        jax.tree.leaves(out["params"])):
            assert np.array_equal(np.asarray(a), np.asarray(b)), \
                "restart is not bitwise identical"

    def test_elastic_width_change(self, tmp_path):
        """Checkpoints are layout-free: a run that saved at width 1 can
        be consumed when the data schedule re-shards (4 -> 2 shards)."""
        cfg, shape = tiny(batch=8)
        ds = D.SyntheticLM(D.for_model(cfg, shape))
        four = np.concatenate([ds.batch(0, s, 4)["tokens"]
                               for s in range(4)])
        two = np.concatenate([ds.batch(0, s, 2)["tokens"]
                              for s in range(2)])
        assert four.shape == two.shape == (8, shape.seq_len)

    def test_heartbeat_straggler_detection(self):
        hb = HeartbeatBoard(4)
        now = 100.0
        for r in range(4):
            hb.beat(r, step=10 if r != 2 else 3, t=now - (20 if r == 3
                                                          else 1))
        h = hb.health(now=now, deadline=10.0, lag_steps=3)
        assert h["dead"] == [3]
        assert h["stragglers"] == [2]

    def test_elastic_plan(self):
        p = ElasticPlan(8)
        assert p.after_failures([5]).n_shards == 4   # keep divisor width
        assert p.after_failures([]).n_shards == 8


# --------------------------------------------------------------------------
# integration: loss decreases
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_training_reduces_loss():
    cfg, shape = tiny(seq=64, batch=8)
    out = run_training(cfg, shape, 120, quiet=True)
    first = np.mean(out["history"][:5])
    last = np.mean(out["history"][-5:])
    assert last < first - 0.15, (first, last)
