"""Measured machine profile + matchbox claim-cursor fast path: the
pure policy derivations, profile staleness/fingerprint gating,
``Comm(tuning="auto")`` consumption, crossover inheritance through
split()/dup(), the sender-side claim cursor's scan accounting, and
chunked persistent collectives through depth-capped matchboxes."""
import json
import time

import numpy as np
import pytest

from repro.core import run_threads
from repro.core import profile as prof_mod

CELL = 4096


def _profile_data(**over) -> dict:
    """Minimal valid measured-field set; override per test."""
    d = {
        "eager_crossover_bytes": 4096,
        "copy_knee_bytes": 256 * 1024,
        "best_chunk_bytes": 1 << 20,
        "cache_gbps": 80.0,
        "dram_gbps": 20.0,
        "strip_scan_us_per_slot": 2.5,
        "spill_promote_us": 20.0,
        "yield_cost_us": 0.5,
    }
    d.update(over)
    return d


# --------------------------------------------------------------------------
# pure policy derivations
# --------------------------------------------------------------------------

class TestDerivations:
    def test_eager_threshold_half_crossover(self):
        assert prof_mod.derive_eager_threshold(4096) == 2048
        assert prof_mod.derive_eager_threshold(1) == 64   # floor

    def test_chunk_floor_measured_argmax_wins(self):
        assert prof_mod.derive_chunk_floor(1024, 2 << 20) == 2 << 20

    def test_chunk_floor_amortization_and_tagwindow_floors(self):
        # 8x-crossover dominates a tiny measured optimum...
        assert prof_mod.derive_chunk_floor(1 << 20, 64 * 1024) == 8 << 20
        # ...and 64 KiB is the absolute floor
        assert prof_mod.derive_chunk_floor(64, 1024) == 64 * 1024

    def test_chunk_floor_zero_disables_chunking(self):
        assert prof_mod.derive_chunk_floor(4096, 0) == 0

    def test_tier_ratio_clamped(self):
        assert prof_mod.derive_tier_ratio(80.0, 20.0) == 4.0
        assert prof_mod.derive_tier_ratio(1e6, 1.0) == 64.0
        assert prof_mod.derive_tier_ratio(1.0, 0.0) == 1.0

    def test_mb_depth_promote_over_scan_clamped(self):
        assert prof_mod.derive_mb_depth(20.0, 2.5) == 8
        assert prof_mod.derive_mb_depth(1.0, 10.0) == 4      # floor
        assert prof_mod.derive_mb_depth(1e4, 1.0) == 32      # cap


# --------------------------------------------------------------------------
# profile file: roundtrip, staleness, fingerprint, env override
# --------------------------------------------------------------------------

class TestProfileFile:
    def test_write_load_roundtrip(self, tmp_path):
        p = prof_mod.write_profile(_profile_data(), tmp_path / "p.json")
        prof = prof_mod.load_profile(p)
        assert prof is not None
        assert prof.eager_crossover == 4096
        assert prof.eager_threshold == 2048
        assert prof.chunk_floor == 1 << 20
        assert prof.tier_ratio == 4.0
        assert prof.mb_depth == 8

    def test_missing_file_is_none(self, tmp_path):
        assert prof_mod.load_profile(tmp_path / "absent.json") is None

    def test_stale_age_rejected_loudly(self, tmp_path):
        p = prof_mod.write_profile(_profile_data(), tmp_path / "p.json")
        data = json.loads(p.read_text())
        data["created"] = time.time() - 48 * 3600
        p.write_text(json.dumps(data))
        with pytest.warns(RuntimeWarning, match="stale"):
            assert prof_mod.load_profile(p) is None

    def test_foreign_host_rejected(self, tmp_path):
        p = prof_mod.write_profile(_profile_data(), tmp_path / "p.json")
        data = json.loads(p.read_text())
        data["host"] = "someone-elses-box|arm64|cpus=2"
        p.write_text(json.dumps(data))
        with pytest.warns(RuntimeWarning, match="fingerprint"):
            assert prof_mod.load_profile(p) is None

    def test_schema_drift_rejected(self, tmp_path):
        p = prof_mod.write_profile(_profile_data(), tmp_path / "p.json")
        data = json.loads(p.read_text())
        data["schema"] = prof_mod.SCHEMA_VERSION + 1
        p.write_text(json.dumps(data))
        with pytest.warns(RuntimeWarning, match="schema"):
            assert prof_mod.load_profile(p) is None

    def test_missing_field_rejected(self, tmp_path):
        p = tmp_path / "p.json"
        data = _profile_data()
        del data["best_chunk_bytes"]
        data.update(schema=prof_mod.SCHEMA_VERSION,
                    host=prof_mod.host_fingerprint(),
                    created=time.time())
        p.write_text(json.dumps(data))
        with pytest.warns(RuntimeWarning, match="best_chunk_bytes"):
            assert prof_mod.load_profile(p) is None

    def test_env_var_path_override(self, tmp_path, monkeypatch):
        p = prof_mod.write_profile(_profile_data(), tmp_path / "p.json")
        monkeypatch.setenv(prof_mod.ENV_PATH, str(p))
        prof = prof_mod.load_profile()
        assert prof is not None and prof.path == p

    def test_max_age_env_override(self, tmp_path, monkeypatch):
        p = prof_mod.write_profile(_profile_data(), tmp_path / "p.json")
        data = json.loads(p.read_text())
        data["created"] = time.time() - 120.0
        p.write_text(json.dumps(data))
        monkeypatch.setenv(prof_mod.ENV_MAX_AGE, "60")
        with pytest.warns(RuntimeWarning, match="old"):
            assert prof_mod.load_profile(p) is None
        monkeypatch.setenv(prof_mod.ENV_MAX_AGE, "3600")
        assert prof_mod.load_profile(p) is not None


# --------------------------------------------------------------------------
# Comm(tuning="auto") consumes every policy, rank-agreed
# --------------------------------------------------------------------------

class TestCommConsumesProfile:
    def test_all_four_policies_applied(self, tmp_path):
        """A fresh profile replaces the init probe (eager threshold),
        the /8 chunk rule, the sqrt hier grouping, and the default
        matchbox depth — identically on every rank."""
        p = prof_mod.write_profile(_profile_data(), tmp_path / "p.json")

        def prog(env):
            from repro.core.collectives import auto_chunk_bytes
            c = env.comm
            # correctness through the tuned data plane
            y = c.allreduce(np.ones(40_000), algo="ring")
            assert np.allclose(y, 2.0)
            return (c.probe_mode, c.eager_threshold, c.mb_slots,
                    auto_chunk_bytes(c, 8 << 20),
                    auto_chunk_bytes(c, 1 << 20),
                    c._tuned["tier_ratio"] if c._tuned else None)

        res = run_threads(2, prog, cell_size=CELL, pool_bytes=64 << 20,
                          comm_kw={"tuning": "auto",
                                   "profile_path": str(p)},
                          timeout=120)
        assert res[0] == res[1]                     # rank-agreed
        mode, thr, mb, cb_big, cb_small, ratio = res[0]
        assert mode == "profile"                    # init probe skipped
        assert thr == 2048                          # crossover / 2
        assert mb == 8                              # measured depth
        assert cb_big == 1 << 20                    # measured argmax
        assert cb_small is None                     # <= 2x floor
        assert ratio == 4.0

    def test_unchunked_optimum_disables_chunking(self, tmp_path):
        p = prof_mod.write_profile(_profile_data(best_chunk_bytes=0),
                                   tmp_path / "p.json")

        def prog(env):
            from repro.core.collectives import auto_chunk_bytes
            return auto_chunk_bytes(env.comm, 64 << 20)

        res = run_threads(2, prog, cell_size=CELL, pool_bytes=32 << 20,
                          comm_kw={"tuning": "auto",
                                   "profile_path": str(p)}, timeout=60)
        assert res == [None, None]

    def test_missing_profile_falls_back_to_heuristics(self, tmp_path):
        """tuning="auto" without a usable profile must not break — it
        degrades to the pre-profile behavior."""
        def prog(env):
            from repro.core.collectives import auto_chunk_bytes
            c = env.comm
            assert c._tuned is None
            return auto_chunk_bytes(c, 8 << 20)

        res = run_threads(2, prog, cell_size=CELL, pool_bytes=32 << 20,
                          comm_kw={"tuning": "auto",
                                   "profile_path":
                                       str(tmp_path / "absent.json")},
                          timeout=60)
        assert res[0] == res[1] == (8 << 20) // 8   # the old /8 rule


# --------------------------------------------------------------------------
# split()/dup() inherit the probed crossover (bugfix)
# --------------------------------------------------------------------------

class TestCrossoverInheritance:
    def test_children_never_reprobe(self, tmp_path):
        """A child communicator inherits the parent's probed crossover
        and tuning verbatim instead of paying (and possibly disagreeing
        on) a fresh probe."""
        p = prof_mod.write_profile(_profile_data(), tmp_path / "p.json")

        def prog(env):
            import repro.core.comm as comm_mod
            c = env.comm
            orig = comm_mod.Comm._probe_eager_threshold

            def boom(self, reps=3):
                raise AssertionError("child communicator re-probed")

            comm_mod.Comm._probe_eager_threshold = boom
            try:
                sub = c.dup()
                sp = c.split(0, key=c.rank)
                env.comm.barrier()
            finally:
                comm_mod.Comm._probe_eager_threshold = orig
            out = []
            for child in (sub, sp):
                out.append((child.probe_mode, child.probed_crossover,
                            child.eager_threshold,
                            child._tuned == c._tuned))
                child.free()
            return c.probed_crossover, c.eager_threshold, out

        res = run_threads(2, prog, cell_size=CELL, pool_bytes=64 << 20,
                          comm_kw={"tuning": "auto",
                                   "profile_path": str(p)},
                          timeout=120)
        for crossover, thr, children in res:
            for mode, child_cross, child_thr, same_tuning in children:
                assert mode == "inherited"
                assert child_cross == crossover
                assert child_thr == thr
                assert same_tuning


# --------------------------------------------------------------------------
# claim cursor: O(1) scans on in-order streams, FIFO preserved
# --------------------------------------------------------------------------

class TestClaimCursor:
    def test_in_order_stream_scans_one_slot_per_claim(self):
        """12 pre-posted receives consumed in post order: the first
        claim full-scans the strip (12 probes, priming the cursor and
        frontier), every later claim probes exactly the cursor slot —
        23 probes total where the cursorless scan paid 144."""
        n = 12
        size = 2 * CELL

        def prog(env):
            st = env.arena.view.stats
            if env.rank == 0:
                env.comm.barrier()               # all entries posted
                s0 = st.mb_slots_scanned
                reqs = [env.comm.isend(1, bytes([i]) * size, tag=i + 1)
                        for i in range(n)]
                env.comm.waitall(reqs, timeout=60)
                return (st.mb_slots_scanned - s0,
                        env.comm.posted_sends)
            bufs = [env.comm.alloc_buffer(size) for _ in range(n)]
            reqs = [env.comm.irecv_into(0, b, tag=i + 1)
                    for i, b in enumerate(bufs)]
            env.comm.barrier()
            env.comm.waitall(reqs, timeout=60)
            return [b.read(0, 1) for b in bufs]

        res = run_threads(2, prog, cell_size=CELL, eager_threshold=0,
                          pool_bytes=64 << 20,
                          comm_kw={"matchbox_slots": n}, timeout=120)
        scanned, posted = res[0]
        assert posted == n                       # every send one-copy
        assert scanned == n + (n - 1)            # 23, not O(n^2)=144
        assert res[1] == [bytes([i]) for i in range(n)]

    def test_out_of_order_tags_fall_back_without_fifo_violation(self):
        """The cursor fast path must NOT claim a newer entry while an
        older live one is merely tag-mismatched: out-of-order tags take
        the full scan and each message still lands in its own posted
        buffer."""
        size = 2 * CELL

        def prog(env):
            if env.rank == 0:
                env.comm.barrier()
                env.comm.send(1, b"\x66" * size, tag=6)  # newer entry
                env.comm.send(1, b"\x55" * size, tag=5)  # older entry
                return env.comm.posted_sends
            pb5 = env.comm.alloc_buffer(size)
            pb6 = env.comm.alloc_buffer(size)
            r5 = env.comm.irecv_into(0, pb5, tag=5)      # pid 1
            r6 = env.comm.irecv_into(0, pb6, tag=6)      # pid 2
            env.comm.barrier()
            env.comm.waitall([r5, r6], timeout=60)
            return pb5.read(0, 1), pb6.read(0, 1)

        res = run_threads(2, prog, cell_size=CELL, eager_threshold=0,
                          pool_bytes=32 << 20, timeout=60)
        assert res[0] == 2                       # both claims hit
        assert res[1] == (b"\x55", b"\x66")      # no cross-delivery


# --------------------------------------------------------------------------
# chunked persistent collectives through a depth-capped matchbox
# --------------------------------------------------------------------------

class TestDepthCappedPersistent:
    def test_chunked_allreduce_init_100pct_hits_at_depth_2(self):
        """12 chunk receives per peer pre-posted through a 2-slot strip:
        10 spill, and each in-flight send must WAIT for the receiver to
        promote the next posting (the persistent schedule's await-claim
        hold) instead of falling back to the staged path. The posted-hit
        rate stays a deterministic 100%."""
        iters = 3
        nelem = 96_000                   # 768 KiB / 8
        chunk = 64 * 1024                # -> 12 sub-round recvs per peer

        def prog(env):
            c = env.comm
            x = np.zeros(nelem)
            req = c.allreduce_init(x, algo="ring", chunk_bytes=chunk)
            h0, r0 = c.posted_sends, c.rndv_sends
            vals = []
            for i in range(iters):
                x[:] = float(i * (env.rank + 1))
                vals.append(float(req.start().wait(120)[0]))
                c.barrier()
            hits, rndv = c.posted_sends - h0, c.rndv_sends - r0
            c.barrier()
            req.free()
            return vals, hits, rndv

        res = run_threads(2, prog, cell_size=CELL,
                          pool_bytes=128 << 20,
                          comm_kw={"matchbox_slots": 2}, timeout=300)
        exp = [float(i * 3) for i in range(iters)]
        for vals, hits, rndv in res:
            assert vals == exp
            # every chunk send of every iteration hit a posted entry
            assert hits == rndv and rndv > 0


# --------------------------------------------------------------------------
# stale-profile surfacing: tuning_status / trace_report / retune
# --------------------------------------------------------------------------

class TestTuningStatus:
    def test_missing_profile_surfaces_reason(self, tmp_path):
        """The one init-time warning is no longer the only trace: a
        silently-heuristic comm carries the rejection reason in
        ``tuning_status``, ``trace_report()["tuning"]`` and the
        metrics registry."""
        def prog(env):
            c = env.comm
            rep = c.trace_report()
            m = c.tracer.metrics.view()
            return (c.tuning_status, rep["tuning"],
                    m["gauges"].get("tuning_profile_loaded"),
                    m["counters"].get("tuning_heuristic_fallback"))

        res = run_threads(2, prog, cell_size=CELL,
                          comm_kw={"tuning": "auto",
                                   "profile_path":
                                       str(tmp_path / "absent.json")})
        status, rep, gauge, fallback = res[0]
        assert status["mode"] == "heuristic"
        assert "no machine profile" in status["reason"]
        assert rep == status
        assert gauge == 0.0
        assert fallback == 1

    def test_stale_profile_reason_names_age(self, tmp_path):
        p = prof_mod.write_profile(_profile_data(), tmp_path / "p.json")
        aged = json.loads(p.read_text())
        aged["created"] = time.time() - 100 * 3600
        p.write_text(json.dumps(aged))

        def prog(env):
            return env.comm.tuning_status

        with pytest.warns(RuntimeWarning, match="stale"):
            res = run_threads(2, prog, cell_size=CELL,
                              comm_kw={"tuning": "auto",
                                       "profile_path": str(p)})
        assert res[0]["mode"] == "heuristic"
        assert "stale machine profile" in res[0]["reason"]

    def test_fresh_profile_reports_profile_mode(self, tmp_path):
        p = prof_mod.write_profile(_profile_data(), tmp_path / "p.json")

        def prog(env):
            m = env.comm.tracer.metrics.view()
            return (env.comm.tuning_status,
                    m["gauges"].get("tuning_profile_loaded"))

        res = run_threads(2, prog, cell_size=CELL, pool_bytes=32 << 20,
                          comm_kw={"tuning": "auto",
                                   "profile_path": str(p)})
        assert res[0] == ({"mode": "profile", "reason": None}, 1.0)

    def test_off_mode_when_tuning_disabled(self):
        def prog(env):
            return env.comm.tuning_status

        res = run_threads(2, prog, cell_size=CELL)
        assert res[0]["mode"] == "off"

    def test_retune_picks_up_new_profile(self, tmp_path):
        """The documented re-profile path: a comm that started
        heuristic (no profile yet) collectively ``retune()``s after a
        sweep wrote one, and the tuned constants apply without a
        restart."""
        p = tmp_path / "late.json"

        def prog(env):
            c = env.comm
            assert c.tuning_status["mode"] == "heuristic"
            if env.rank == 0:
                prof_mod.write_profile(_profile_data(), p)
            c.barrier()
            status = c.retune()
            # tuned data plane still correct after the live switch
            y = c.allreduce(np.ones(10_000))
            assert np.allclose(y, 2.0)
            return (status, c.eager_threshold, c.probe_mode,
                    c._tuned["crossover"])

        res = run_threads(2, prog, cell_size=CELL, pool_bytes=64 << 20,
                          comm_kw={"tuning": "auto",
                                   "profile_path": str(p)},
                          timeout=120)
        assert res[0] == res[1]                    # rank-agreed
        status, thr, mode, crossover = res[0]
        assert status == {"mode": "profile", "reason": None}
        assert thr == 2048                         # crossover / 2
        assert mode == "profile"
        assert crossover == 4096

    def test_retune_requires_auto(self):
        def prog(env):
            try:
                env.comm.retune()
                return False
            except RuntimeError:
                return True

        assert all(run_threads(2, prog, cell_size=CELL))

    def test_retune_keeps_explicit_eager_threshold(self, tmp_path):
        """An explicitly-passed eager_threshold is a user decision —
        retune() must not clobber it with the profile derivation."""
        p = prof_mod.write_profile(_profile_data(), tmp_path / "p.json")

        def prog(env):
            env.comm.retune()
            return env.comm.eager_threshold

        res = run_threads(2, prog, cell_size=CELL, pool_bytes=32 << 20,
                          eager_threshold=512,
                          comm_kw={"tuning": "auto",
                                   "profile_path": str(p)})
        assert res == [512, 512]
