"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see the real single
CPU device; multi-device tests spawn subprocesses that set the flag
themselves (see tests/test_distributed.py).

If hypothesis is not installed (it is an optional dev dependency, see
requirements-dev.txt), a deterministic lightweight fallback is installed
into sys.modules BEFORE test modules import it, so the suite still
collects and the property tests still run (without shrinking)."""
import importlib.util
import os
import pathlib
import sys

import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        pathlib.Path(__file__).parent / "_hypothesis_fallback.py")
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
else:
    # real hypothesis: a deterministic CI profile (fixed derivation, no
    # wall-clock deadline — the protocol fuzz suite spins up whole rank
    # teams per example). Activated in CI; selectable locally with
    # HYPOTHESIS_PROFILE=ci.
    hypothesis.settings.register_profile(
        "ci", deadline=None, derandomize=True, print_blob=True)
    if os.environ.get("CI") or os.environ.get("HYPOTHESIS_PROFILE") \
            == "ci":
        hypothesis.settings.load_profile("ci")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
