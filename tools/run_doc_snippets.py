"""Execute the fenced ``python`` blocks of markdown docs.

CI's ``docs`` job runs this over README.md and docs/architecture.md so
every documented snippet is a working program, not prose that rotted.
Each block runs in its own subprocess from the repository root with
``PYTHONPATH=src`` prepended, so snippets are written exactly as a
user would run them.

A block whose FIRST line starts with ``# doc: no-exec`` is skipped —
the marker (with a reason) is for intentional fragments that reference
surrounding context (a live ``comm``, a training loop) and cannot be
self-contained without burying the point.

Usage:
    python tools/run_doc_snippets.py README.md docs/architecture.md
    python tools/run_doc_snippets.py --list README.md   # show, don't run
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
import tempfile
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
FENCE_RE = re.compile(r"^```python[ \t]*$")
NO_EXEC = "# doc: no-exec"


def extract_blocks(path: Path) -> list[tuple[int, str]]:
    """Return ``(start_line, source)`` for every fenced python block."""
    blocks = []
    lines = path.read_text().splitlines()
    i = 0
    while i < len(lines):
        if FENCE_RE.match(lines[i]):
            start = i + 2                      # 1-based first code line
            body = []
            i += 1
            while i < len(lines) and not lines[i].startswith("```"):
                body.append(lines[i])
                i += 1
            if i >= len(lines):
                raise SystemExit(f"{path}:{start}: unterminated "
                                 f"```python fence")
            blocks.append((start, "\n".join(body) + "\n"))
        i += 1
    return blocks


def run_block(path: Path, line: int, src: str,
              timeout: float) -> tuple[bool, str]:
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(REPO_ROOT / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    with tempfile.NamedTemporaryFile(
            "w", suffix=".py", prefix=f"doc_{path.stem}_L{line}_",
            delete=False) as f:
        f.write(src)
        tmp = f.name
    try:
        proc = subprocess.run(
            [sys.executable, tmp], cwd=REPO_ROOT, env=env,
            capture_output=True, text=True, timeout=timeout)
        ok = proc.returncode == 0
        out = (proc.stdout + proc.stderr).strip()
        return ok, out
    except subprocess.TimeoutExpired:
        return False, f"timed out after {timeout:.0f}s"
    finally:
        os.unlink(tmp)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description="run every fenced python block of the given "
                    "markdown files (skipping '# doc: no-exec' blocks)")
    p.add_argument("files", nargs="+", type=Path)
    p.add_argument("--timeout", type=float, default=600.0,
                   help="per-block timeout in seconds (default 600)")
    p.add_argument("--list", action="store_true",
                   help="list the blocks and whether each would run")
    args = p.parse_args(argv)

    failures = 0
    ran = skipped = 0
    for path in args.files:
        if not path.exists():
            print(f"MISSING  {path}")
            failures += 1
            continue
        for line, src in extract_blocks(path):
            where = f"{path}:{line}"
            if src.lstrip().startswith(NO_EXEC):
                skipped += 1
                print(f"SKIP     {where}  ({NO_EXEC})")
                continue
            if args.list:
                print(f"WOULD RUN {where}")
                continue
            ok, out = run_block(path, line, src, args.timeout)
            ran += 1
            if ok:
                print(f"OK       {where}")
            else:
                failures += 1
                print(f"FAIL     {where}\n{'-' * 60}\n{out}\n{'-' * 60}")
    print(f"\n{ran} block(s) ran, {skipped} skipped, "
          f"{failures} failure(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
