"""Benchmark harness entrypoint: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only table1 ...]

Writes CSVs under artifacts/bench/ and prints per-benchmark summaries.
The roofline section reads the dry-run artifacts (run
``python -m repro.launch.dryrun`` first for the full 80-cell table).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (fig5_8_osu, fig9_cellsize, fig10_scaling,
                        fig11_coherence, roofline, table1_interconnects)

BENCHES = {
    "table1": table1_interconnects.main,
    "fig5_8": fig5_8_osu.main,
    "fig9": fig9_cellsize.main,
    "fig10": fig10_scaling.main,
    "fig11": fig11_coherence.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()

    names = args.only or list(BENCHES) + ["roofline"]
    failures = []
    for name in names:
        print(f"\n=== {name} " + "=" * max(0, 60 - len(name)))
        t0 = time.perf_counter()
        try:
            if name == "roofline":
                rows = roofline.run()
                ok = [r for r in rows if r[3] not in ("SKIP", "FAIL")]
                skip = [r for r in rows if r[3] == "SKIP"]
                fail = [r for r in rows if r[3] == "FAIL"]
                print(f"roofline cells: {len(ok)} ok, {len(skip)} skip, "
                      f"{len(fail)} fail (CSV: artifacts/bench/"
                      f"roofline_baseline.csv)")
                if fail:
                    failures.append(name)
            else:
                BENCHES[name](quick=args.quick)
        except Exception:
            traceback.print_exc()
            failures.append(name)
        print(f"--- {name} done in {time.perf_counter() - t0:.1f}s")
    if failures:
        print(f"\nFAILED benchmarks: {failures}")
        sys.exit(1)
    print("\nall benchmarks completed; CSVs in artifacts/bench/")


if __name__ == "__main__":
    main()
