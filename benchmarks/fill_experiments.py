"""Fill EXPERIMENTS.md placeholders (TABLE:ROOFLINE, TABLE:PERF, CELL:*)
from the dry-run artifacts. Idempotent: reads EXPERIMENTS.md.in if present,
else the current EXPERIMENTS.md (first run renames it to .in).

  PYTHONPATH=src python -m benchmarks.fill_experiments
"""
from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
ART = ROOT / "artifacts" / "dryrun"


def load(variant: str) -> dict:
    out = {}
    base = ART / variant
    if base.exists():
        for p in sorted(base.glob("*/*.json")):
            r = json.loads(p.read_text())
            out[(r["mesh"], r["arch"], r["shape"])] = r
    return out


def roofline_table() -> str:
    rows = ["| mesh | arch | shape | step | compute_s | memory_s | "
            "collective_s | dominant | useful | frac | mem/dev |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for key, rec in sorted(load("baseline").items()):
        mesh, arch, shape = key
        if rec["status"] == "skip":
            rows.append(f"| {mesh} | {arch} | {shape} | SKIP | | | | | | | "
                        f"{rec['why'].split(':')[0]} |")
            continue
        if rec["status"] != "ok":
            rows.append(f"| {mesh} | {arch} | {shape} | FAIL | | | | | | | |")
            continue
        r = rec["roofline"]
        mem = rec.get("memory", {}).get("live_bytes_per_device", 0)
        rows.append(
            f"| {mesh} | {arch} | {shape} | {rec.get('step', '')} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | {r['bottleneck']} "
            f"| {r['useful_flops_ratio']:.3f} "
            f"| {r['roofline_fraction']:.4f} | {mem / 2**30:.1f}GiB |")
    return "\n".join(rows)


def cell_line(variant: str, mesh: str, arch: str, shape: str) -> str:
    rec = load(variant).get((mesh, arch, shape))
    if rec is None or rec.get("status") != "ok":
        return f"(variant {variant}: not available)"
    r = rec["roofline"]
    mem = rec.get("memory", {}).get("live_bytes_per_device", 0)
    return (f"compute {r['compute_s']:.3e}s, memory {r['memory_s']:.3e}s, "
            f"collective {r['collective_s']:.3e}s, dominant "
            f"{r['bottleneck']}, frac {r['roofline_fraction']:.4f}, "
            f"mem/dev {mem / 2**30:.1f} GiB")


PERF_CELLS = [
    ("A", "pod16x16", "smollm-135m", "train_4k",
     ["baseline", "attnchunk512", "seqshard", "seqshard_chunk"]),
    ("B", "pod16x16", "llama3-8b", "decode_32k",
     ["baseline", "decodeopt", "servetp", "kvbatch", "flashdecode"]),
    ("C", "pod2x16x16", "dbrx-132b", "train_4k",
     ["baseline", "moeffntp", "zero3", "ep_a2a"]),
]


def perf_table() -> str:
    rows = ["| cell | variant | compute_s | memory_s | collective_s | "
            "dominant | frac | mem/dev |",
            "|---|---|---|---|---|---|---|---|"]
    for tag, mesh, arch, shape, variants in [
            (c[0], c[1], c[2], c[3], c[4]) for c in PERF_CELLS]:
        for v in variants:
            rec = load(v).get((mesh, arch, shape))
            if rec is None or rec.get("status") != "ok":
                continue
            r = rec["roofline"]
            mem = rec.get("memory", {}).get("live_bytes_per_device", 0)
            rows.append(
                f"| {tag}: {arch}/{shape}/{mesh} | {v} "
                f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
                f"| {r['collective_s']:.2e} | {r['bottleneck']} "
                f"| {r['roofline_fraction']:.4f} | {mem / 2**30:.1f}GiB |")
    return "\n".join(rows)


def summary_table() -> str:
    rows = ["| cell | baseline dominant term | best variant | dominant "
            "term after | improvement | frac before -> after |",
            "|---|---|---|---|---|---|"]
    best = {"A": "seqshard", "B": "flashdecode", "C": "ep_a2a"}
    for tag, mesh, arch, shape, _ in [
            (c[0], c[1], c[2], c[3], c[4]) for c in PERF_CELLS]:
        b = load("baseline").get((mesh, arch, shape))
        o = load(best[tag]).get((mesh, arch, shape))
        if not b or not o or b.get("status") != "ok" \
                or o.get("status") != "ok":
            continue
        br, orr = b["roofline"], o["roofline"]
        dom = br["bottleneck"]
        odom = orr["bottleneck"]
        bb, oo = br[f"{dom}_s"], orr[f"{dom}_s"]
        rows.append(
            f"| {tag}: {arch}/{shape} | {dom} {bb:.2e}s | {best[tag]} "
            f"| {odom} {orr[odom + '_s']:.2e}s"
            f" | {bb / oo:.2f}x on {dom} "
            f"| {br['roofline_fraction']:.4f} -> "
            f"{orr['roofline_fraction']:.4f} |")
    return "\n".join(rows)


def optimized_table() -> str:
    """Aggregate beyond-paper gains: optimized preset vs baseline for
    every cell where both compiled."""
    base, opt = load("baseline"), load("optimized")
    rows = ["| mesh | arch | shape | dominant (base) | dom term base -> "
            "opt | frac base -> opt |",
            "|---|---|---|---|---|---|"]
    gains = []
    for key in sorted(opt):
        b, o = base.get(key), opt[key]
        if not b or b.get("status") != "ok" or o.get("status") != "ok":
            continue
        br, orr = b["roofline"], o["roofline"]
        dom = br["bottleneck"]
        bb, oo = br[f"{dom}_s"], orr[f"{dom}_s"]
        gains.append(bb / max(oo, 1e-12))
        rows.append(
            f"| {key[0]} | {key[1]} | {key[2]} | {dom} "
            f"| {bb:.2e} -> {oo:.2e} ({bb / max(oo, 1e-12):.2f}x) "
            f"| {br['roofline_fraction']:.4f} -> "
            f"{orr['roofline_fraction']:.4f} |")
    if gains:
        import math
        gm = math.exp(sum(math.log(g) for g in gains) / len(gains))
        rows.append(f"| | | **geomean over {len(gains)} cells** | | "
                    f"**{gm:.2f}x on the dominant term** | |")
    return "\n".join(rows)


def main() -> None:
    src = ROOT / "EXPERIMENTS.md.in"
    if not src.exists():
        (ROOT / "EXPERIMENTS.md").rename(src)
    text = src.read_text()
    text = text.replace("TABLE:ROOFLINE", roofline_table())
    text = text.replace("TABLE:PERF", perf_table())
    text = text.replace("TABLE:SUMMARY", summary_table())
    text = text.replace("CELL:A2", cell_line("seqshard", "pod16x16",
                                             "smollm-135m", "train_4k"))
    text = text.replace("CELL:A3", cell_line("seqshard_chunk", "pod16x16",
                                             "smollm-135m", "train_4k"))
    text = text.replace("CELL:B3", cell_line("flashdecode", "pod16x16",
                                             "llama3-8b", "decode_32k"))
    text = text.replace("CELL:C2", cell_line("zero3", "pod2x16x16",
                                             "dbrx-132b", "train_4k"))
    text = text.replace("CELL:C3", cell_line("ep_a2a", "pod2x16x16",
                                             "dbrx-132b", "train_4k"))
    text = text.replace("TABLE:OPTIMIZED", optimized_table())
    (ROOT / "EXPERIMENTS.md").write_text(text)
    print("EXPERIMENTS.md filled from artifacts")


if __name__ == "__main__":
    main()
