"""Roofline report: reads artifacts/dryrun/<variant>/ and prints the
per-(arch x shape x mesh) table of the three roofline terms.

Usage:
  PYTHONPATH=src python -m benchmarks.roofline [--variant baseline]
  PYTHONPATH=src python -m benchmarks.roofline --compare baseline opt1
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.common import write_csv

ART = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def load(variant: str) -> list[dict]:
    out = []
    base = ART / variant
    if not base.exists():
        return out
    for p in sorted(base.glob("*/*.json")):
        out.append(json.loads(p.read_text()))
    return out


def table(variant: str = "baseline") -> list[list]:
    rows = []
    for rec in load(variant):
        if rec.get("status") == "skip":
            rows.append([rec["mesh"], rec["arch"], rec["shape"], "SKIP",
                         "", "", "", "", "", "", rec.get("why", "")])
            continue
        if rec.get("status") != "ok":
            rows.append([rec["mesh"], rec["arch"], rec["shape"], "FAIL",
                         "", "", "", "", "", "",
                         rec.get("error", "")[:60]])
            continue
        r = rec["roofline"]
        mem = rec.get("memory", {}).get("live_bytes_per_device", 0)
        rows.append([
            rec["mesh"], rec["arch"], rec["shape"], rec.get("step", ""),
            f"{r['compute_s']:.3e}", f"{r['memory_s']:.3e}",
            f"{r['collective_s']:.3e}", r["bottleneck"],
            f"{r['useful_flops_ratio']:.3f}",
            f"{r['roofline_fraction']:.4f}",
            f"{mem / 2**30:.2f}GiB",
        ])
    return rows


HEADER = ["mesh", "arch", "shape", "step", "compute_s", "memory_s",
          "collective_s", "bottleneck", "useful_ratio", "roofline_frac",
          "mem/dev"]


def run(quick: bool = False, variant: str = "baseline") -> list[list]:
    rows = table(variant)
    write_csv(f"roofline_{variant}", HEADER, rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--compare", nargs=2, metavar=("BASE", "OPT"))
    args = ap.parse_args()
    if args.compare:
        base = {(r["mesh"], r["arch"], r["shape"]): r
                for r in load(args.compare[0]) if r.get("status") == "ok"}
        opt = {(r["mesh"], r["arch"], r["shape"]): r
               for r in load(args.compare[1]) if r.get("status") == "ok"}
        print(f"{'cell':58s} {'dom term':>10s} {'before':>10s} "
              f"{'after':>10s} {'delta':>8s}")
        for key in sorted(opt):
            if key not in base:
                continue
            b, o = base[key]["roofline"], opt[key]["roofline"]
            dom = b["bottleneck"]
            bb, oo = b[f"{dom}_s"], o[f"{dom}_s"]
            print(f"{'/'.join(key):58s} {dom:>10s} {bb:10.3e} {oo:10.3e} "
                  f"{(oo / bb - 1) * 100:7.1f}%  frac "
                  f"{b['roofline_fraction']:.4f}->{o['roofline_fraction']:.4f}")
        return
    rows = run(variant=args.variant)
    print(f"{'mesh':12s} {'arch':24s} {'shape':12s} {'step':13s} "
          f"{'compute':>10s} {'memory':>10s} {'collect':>10s} "
          f"{'dominant':>10s} {'useful':>7s} {'frac':>7s} {'mem/dev':>9s}")
    for r in rows:
        print(f"{r[0]:12s} {r[1]:24s} {r[2]:12s} {str(r[3]):13s} "
              f"{r[4]:>10s} {r[5]:>10s} {r[6]:>10s} {r[7]:>10s} "
              f"{r[8]:>7s} {r[9]:>7s} {r[10]:>9s}")


if __name__ == "__main__":
    main()
