"""Roofline report + the ERT-style per-host machine-profile sweep.

Two modes:

* report (default) — reads artifacts/dryrun/<variant>/ and prints the
  per-(arch x shape x mesh) table of the three roofline terms.
* ``--profile`` — measures THIS host the way the Empirical Roofline
  Toolkit measures one: copy/reduce bandwidth ceilings per working-set
  size (the knee locates the cache tier), the pt2pt eager-vs-posted
  crossover over the real wire paths, an end-to-end chunk-size sweep
  over a real 2-rank chunked iallreduce (the measured argmax becomes
  the tuned pipeline chunk), the cooperative engine's per-yield
  round-trip cost, and the matchbox strip-scan / spill-promote costs.
  Results are written as a cached,
  schema-versioned ``artifacts/bench/machine_profile.json`` that
  ``Comm(tuning="auto")`` consumes for every tuned constant (see
  ``repro.core.profile``). ``--smoke`` shrinks the sweep for CI.

Usage:
  PYTHONPATH=src python -m benchmarks.roofline [--variant baseline]
  PYTHONPATH=src python -m benchmarks.roofline --compare baseline opt1
  PYTHONPATH=src python -m benchmarks.roofline --profile [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import write_csv

ART = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


# --------------------------------------------------------------------------
# machine-profile sweep (ERT shape: fixed total volume per working set,
# best-of-trials to reject scheduler noise)
# --------------------------------------------------------------------------

def _bw_curve(kind: str, sizes: list[int], total_bytes: int,
              trials: int = 3) -> list[float]:
    """GB/s per working-set size. ``copy`` moves 2x the set per pass
    (read + write), ``reduce`` 3x (two operand reads + one write) —
    the byte accounting ERT uses for its ceilings."""
    out = []
    for ws in sizes:
        if kind == "copy":
            src = np.ones(ws, np.uint8)
            dst = np.empty(ws, np.uint8)
            per_pass = 2 * ws

            def body():
                dst[:] = src
        else:
            n = max(1, ws // 4)
            a = np.ones(n, np.float32)
            b = np.ones(n, np.float32)
            c = np.empty(n, np.float32)
            per_pass = 3 * n * 4

            def body():
                np.add(a, b, out=c)
        reps = max(3, total_bytes // per_pass)
        body()                                   # warm / page in
        best = 0.0
        for _ in range(trials):
            t0 = time.perf_counter()
            for _ in range(reps):
                body()
            dt = time.perf_counter() - t0
            best = max(best, reps * per_pass / dt / 1e9)
        out.append(best)
    return out


def _knee(sizes: list[int], gbps: list[float],
          fraction: float) -> tuple[int, float, float]:
    """(knee_bytes, peak_gbps, plateau_gbps): the knee is the LARGEST
    working set still delivering ``fraction`` of the peak."""
    peak = max(gbps)
    knee = sizes[0]
    for ws, bw in zip(sizes, gbps):
        if bw >= fraction * peak:
            knee = ws
    return knee, peak, gbps[-1]


def _pt2pt_sweep(sizes: list[int], reps: int,
                 cell_size: int = 4096) -> dict:
    """Eager vs posted-rendezvous round-trip time per message size over
    the REAL wire paths (two thread ranks, the init-probe exchange
    pattern), plus the first size where posted wins."""
    from repro.core.pt2pt import PoolBuffer
    from repro.core.runtime import run_threads

    _PRB = 0x7F000000 + 0x4000           # reserved probe tag window

    def fn(env):
        comm = env.comm
        peer = comm.rank ^ 1
        scratch = memoryview(bytearray(sizes[-1]))
        dst = comm.alloc_buffer(sizes[-1]) if comm._pool_aliasable() \
            else bytearray(sizes[-1])

        def exchange(s: int) -> None:
            rreq = comm.irecv_into(peer, dst, tag=_PRB + 1,
                                   _internal=True)
            comm.send(peer, b"", tag=_PRB + 2, _internal=True)  # credit
            comm.recv(peer, tag=_PRB + 2, _internal=True)
            sreq = comm.isend(peer, scratch[:s], tag=_PRB + 1,
                              _internal=True)
            rreq.wait()
            sreq.wait()

        def timed(s: int, threshold: int) -> float:
            comm.eager_threshold = threshold
            exchange(s)                          # warm / sync
            t0 = time.perf_counter()
            for _ in range(reps):
                exchange(s)
            return (time.perf_counter() - t0) / reps * 1e6

        # every size on both ranks, in lockstep (a rank must not stop
        # early — its partner would hang mid-sweep)
        rows = [(timed(s, 1 << 40), timed(s, 0)) for s in sizes]
        if isinstance(dst, PoolBuffer):
            dst.free()
        return rows

    rows = run_threads(2, fn, pool_bytes=max(32 << 20, 8 * sizes[-1]),
                       cell_size=cell_size)[0]
    eager_us = [r[0] for r in rows]
    posted_us = [r[1] for r in rows]
    crossover = 2 * sizes[-1]            # eager wins everywhere probed
    for s, te, tp in zip(sizes, eager_us, posted_us):
        if tp <= te:
            crossover = s
            break
    return {"sizes": sizes, "eager_us": eager_us,
            "posted_us": posted_us, "crossover": crossover}


# a chunk size must beat unchunked by this factor to count as a
# chunking win — below it, the measured difference is warm-up / drift
# noise and the safe answer is "don't chunk"
CHUNK_WIN_MARGIN = 1.05


def _chunk_sweep(payload: int, chunks: list[int], iters: int = 3,
                 timeout: float = 300.0) -> dict:
    """End-to-end chunk-size sweep: a REAL 2-rank ring iallreduce timed
    at each candidate chunk size (plus unchunked), candidates
    INTERLEAVED per iteration so drifting host throughput hits all of
    them equally, min-of-iters on the slowest rank. This is the only
    measurement that sees both forces the chunk size trades off —
    cache-resident reduce tiles (favoring small chunks, visible in the
    bandwidth knee) vs per-chunk engine round-trips (favoring large
    ones) — so the tuned chunk is the measured argmax, not a model.
    ``best_chunk_bytes`` is 0 when no candidate beat unchunked by
    ``CHUNK_WIN_MARGIN`` (chunking disabled on this host)."""
    from repro.core.runtime import run_processes

    cands: list[int | None] = [None] + list(chunks)

    def prog(env):
        c = env.comm
        x = np.full(payload // 8, float(env.rank + 1))
        for cb in cands:                 # warm + compile every schedule
            c.iallreduce(x, algo="ring", chunk_bytes=cb).wait(None)
        times = [float("inf")] * len(cands)
        for _ in range(iters):
            for i, cb in enumerate(cands):
                c.barrier()
                t0 = time.perf_counter()
                c.iallreduce(x, algo="ring", chunk_bytes=cb).wait(None)
                times[i] = min(times[i], time.perf_counter() - t0)
        return times

    res = run_processes(2, prog, pool_bytes=max(256 << 20, 16 * payload),
                        cell_size=16384, timeout=timeout)
    times = [max(r[i] for r in res) for i in range(len(cands))]
    t_un, t_ch = times[0], times[1:]
    i_best = min(range(len(chunks)), key=lambda i: t_ch[i])
    best = chunks[i_best] if t_ch[i_best] * CHUNK_WIN_MARGIN < t_un \
        else 0
    return {"payload": payload, "chunks": list(chunks),
            "mibps": [payload / t / (1 << 20) for t in t_ch],
            "unchunked_mibps": payload / t_un / (1 << 20),
            "best_chunk_bytes": best}


def _matchbox_micro(reps: int = 20000) -> tuple[float, float]:
    """(strip_scan_us_per_slot, spill_promote_us) measured on a live
    Matchbox over a local pool: the scan cost is what every claim pays
    per strip slot (pid + tag loads); the spill-promote cost is one
    posting cycle (the entry-field stores a promotion replays, plus
    the overflow-queue hop)."""
    from collections import deque

    from repro.core.coherence import CoherentView
    from repro.core.pool import LocalPool
    from repro.core.pt2pt import Matchbox

    slots = 8
    pool = LocalPool(max(1 << 16, Matchbox.region_bytes(2, slots)))
    v = CoherentView(pool, "coherent")
    mb = Matchbox(v, 0, 2, slots, initialize=True)
    t0 = time.perf_counter()
    for _ in range(reps):
        for s in range(slots):
            off = mb.entry_off(0, 1, s)
            v.nt_load_u64(off)
            v.nt_load_u64(off + 8)
    scan_us = (time.perf_counter() - t0) / (reps * slots) * 1e6
    q: deque = deque()
    t0 = time.perf_counter()
    for i in range(reps):
        q.append(i)
        q.popleft()
        mb.post(0, 1, i % slots, i + 1, 7, 128, 4096)
        v.nt_store_u64(mb.entry_off(0, 1, i % slots), 0)
    promote_us = (time.perf_counter() - t0) / reps * 1e6
    return scan_us, promote_us


def write_trace(payload: int = 4 << 20) -> list[Path]:
    """Small traced run for timeline inspection: a 2-rank chunked ring
    iallreduce with the flight recorder on, per-rank dumps written to
    ``artifacts/bench/trace/roofline_rank{r}.json`` (merge with
    ``python -m repro.trace merge``). This is the profile sweep's
    workload seen through the recorder — per-chunk schedule lanes plus
    engine-tick occupancy — not a performance measurement."""
    from repro.core.runtime import run_processes
    from repro.core.trace import load_dump, summarize_dumps

    out_dir = Path(__file__).resolve().parent.parent / "artifacts" \
        / "bench" / "trace"

    def prog(env):
        c = env.comm
        x = np.full(payload // 8, float(env.rank + 1))
        c.iallreduce(x, algo="ring", chunk_bytes="auto").wait(None)
        c.barrier()
        return c.trace_dump(out_dir / f"roofline_rank{env.rank}.json")

    paths = run_processes(2, prog, pool_bytes=max(256 << 20, 16 * payload),
                          cell_size=16384, comm_kw={"trace": True},
                          timeout=300)
    print(summarize_dumps([load_dump(p) for p in paths]))
    for p in paths:
        print(f"  {p}")
    return [Path(p) for p in paths]


def sweep_profile(smoke: bool = False) -> dict:
    """Run the full ERT-style sweep and return the profile fields."""
    from benchmarks.fig5_8_osu import SANDBOX_YIELD_US, yield_cost_us
    from repro.core import profile as _profile

    if smoke:
        bw_sizes = [1 << s for s in range(15, 23)]      # 32 KiB..4 MiB
        total, pt_reps = 8 << 20, 3
        pt_sizes = [1024, 4096, 16384, 32768]
        mb_reps = 4000
        ch_payload, ch_iters = 4 << 20, 3
        ch_sizes = [256 << 10, 512 << 10, 1 << 20, 2 << 20]
    else:
        bw_sizes = [1 << s for s in range(14, 27)]      # 16 KiB..64 MiB
        total, pt_reps = 64 << 20, 8
        pt_sizes = [1 << s for s in range(10, 17)]      # 1 KiB..64 KiB
        mb_reps = 20000
        ch_payload, ch_iters = 8 << 20, 5
        ch_sizes = [128 << 10, 256 << 10, 512 << 10,
                    1 << 20, 2 << 20, 4 << 20]
    copy_gbps = _bw_curve("copy", bw_sizes, total)
    reduce_gbps = _bw_curve("reduce", bw_sizes, total)
    ck, cpeak, cplat = _knee(bw_sizes, copy_gbps, _profile.KNEE_FRACTION)
    rk, _, _ = _knee(bw_sizes, reduce_gbps, _profile.KNEE_FRACTION)
    pt = _pt2pt_sweep(pt_sizes, pt_reps)
    ch = _chunk_sweep(ch_payload, ch_sizes, ch_iters)
    scan_us, promote_us = _matchbox_micro(mb_reps)
    y = yield_cost_us()
    data = {
        "smoke": smoke,
        "copy": {"sizes": bw_sizes, "gbps": copy_gbps},
        "reduce": {"sizes": bw_sizes, "gbps": reduce_gbps},
        # conservative: the shallower of the two knees keeps a reduce
        # round's three streams inside the fast tier too
        "copy_knee_bytes": min(ck, rk),
        "cache_gbps": cpeak,
        "dram_gbps": cplat,
        "pt2pt": {"sizes": pt["sizes"], "eager_us": pt["eager_us"],
                  "posted_us": pt["posted_us"]},
        "eager_crossover_bytes": pt["crossover"],
        "chunk_sweep": ch,
        "best_chunk_bytes": ch["best_chunk_bytes"],
        "strip_scan_us_per_slot": scan_us,
        "spill_promote_us": promote_us,
        "yield_cost_us": y,
        "sandboxed": y >= SANDBOX_YIELD_US,
    }
    return data


def write_machine_profile(smoke: bool = False,
                          path: str | None = None) -> Path:
    """Sweep + write artifacts/bench/machine_profile.json; prints the
    measured ceilings and every derived tuning constant."""
    from repro.core import profile as _profile

    data = sweep_profile(smoke)
    out = _profile.write_profile(data, path)
    prof = _profile.MachineProfile(json.loads(out.read_text()), out)
    print(f"machine profile -> {out}  "
          f"({'smoke' if smoke else 'full'} sweep)")
    print(f"  copy peak {data['cache_gbps']:.1f} GB/s, plateau "
          f"{data['dram_gbps']:.1f} GB/s, knee "
          f"{data['copy_knee_bytes'] / 1024:.0f} KiB")
    print(f"  pt2pt crossover {data['eager_crossover_bytes']} B, "
          f"yield {data['yield_cost_us']:.2f} us"
          f"{' (SANDBOXED)' if data['sandboxed'] else ''}")
    ch = data["chunk_sweep"]
    best = data["best_chunk_bytes"]
    best_bw = ch["unchunked_mibps"] if best == 0 else max(ch["mibps"])
    print(f"  chunk sweep @ {ch['payload'] >> 20} MiB: unchunked "
          f"{ch['unchunked_mibps']:.0f} MiB/s, best "
          f"{'unchunked' if best == 0 else f'{best >> 10} KiB'} "
          f"({best_bw:.0f} MiB/s)")
    print(f"  matchbox scan {data['strip_scan_us_per_slot']:.3f} "
          f"us/slot, spill-promote {data['spill_promote_us']:.3f} us")
    print(f"  derived: eager_threshold={prof.eager_threshold} "
          f"chunk_floor={prof.chunk_floor} "
          f"tier_ratio={prof.tier_ratio:.2f} mb_depth={prof.mb_depth}")
    return out


def load(variant: str) -> list[dict]:
    out = []
    base = ART / variant
    if not base.exists():
        return out
    for p in sorted(base.glob("*/*.json")):
        out.append(json.loads(p.read_text()))
    return out


def table(variant: str = "baseline") -> list[list]:
    rows = []
    for rec in load(variant):
        if rec.get("status") == "skip":
            rows.append([rec["mesh"], rec["arch"], rec["shape"], "SKIP",
                         "", "", "", "", "", "", rec.get("why", "")])
            continue
        if rec.get("status") != "ok":
            rows.append([rec["mesh"], rec["arch"], rec["shape"], "FAIL",
                         "", "", "", "", "", "",
                         rec.get("error", "")[:60]])
            continue
        r = rec["roofline"]
        mem = rec.get("memory", {}).get("live_bytes_per_device", 0)
        rows.append([
            rec["mesh"], rec["arch"], rec["shape"], rec.get("step", ""),
            f"{r['compute_s']:.3e}", f"{r['memory_s']:.3e}",
            f"{r['collective_s']:.3e}", r["bottleneck"],
            f"{r['useful_flops_ratio']:.3f}",
            f"{r['roofline_fraction']:.4f}",
            f"{mem / 2**30:.2f}GiB",
        ])
    return rows


HEADER = ["mesh", "arch", "shape", "step", "compute_s", "memory_s",
          "collective_s", "bottleneck", "useful_ratio", "roofline_frac",
          "mem/dev"]


def run(quick: bool = False, variant: str = "baseline") -> list[list]:
    rows = table(variant)
    write_csv(f"roofline_{variant}", HEADER, rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--compare", nargs=2, metavar=("BASE", "OPT"))
    ap.add_argument("--profile", action="store_true",
                    help="run the ERT-style host sweep and write "
                         "artifacts/bench/machine_profile.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized profile sweep")
    ap.add_argument("--out", default=None,
                    help="profile output path override")
    ap.add_argument("--trace", action="store_true",
                    help="run a small traced 2-rank chunked iallreduce "
                         "and write per-rank flight-recorder dumps to "
                         "artifacts/bench/trace/")
    args = ap.parse_args()
    if args.trace:
        write_trace()
        return
    if args.profile:
        write_machine_profile(smoke=args.smoke, path=args.out)
        return
    if args.compare:
        base = {(r["mesh"], r["arch"], r["shape"]): r
                for r in load(args.compare[0]) if r.get("status") == "ok"}
        opt = {(r["mesh"], r["arch"], r["shape"]): r
               for r in load(args.compare[1]) if r.get("status") == "ok"}
        print(f"{'cell':58s} {'dom term':>10s} {'before':>10s} "
              f"{'after':>10s} {'delta':>8s}")
        for key in sorted(opt):
            if key not in base:
                continue
            b, o = base[key]["roofline"], opt[key]["roofline"]
            dom = b["bottleneck"]
            bb, oo = b[f"{dom}_s"], o[f"{dom}_s"]
            print(f"{'/'.join(key):58s} {dom:>10s} {bb:10.3e} {oo:10.3e} "
                  f"{(oo / bb - 1) * 100:7.1f}%  frac "
                  f"{b['roofline_fraction']:.4f}->{o['roofline_fraction']:.4f}")
        return
    rows = run(variant=args.variant)
    print(f"{'mesh':12s} {'arch':24s} {'shape':12s} {'step':13s} "
          f"{'compute':>10s} {'memory':>10s} {'collect':>10s} "
          f"{'dominant':>10s} {'useful':>7s} {'frac':>7s} {'mem/dev':>9s}")
    for r in rows:
        print(f"{r[0]:12s} {r[1]:24s} {r[2]:12s} {str(r[3]):13s} "
              f"{r[4]:>10s} {r[5]:>10s} {r[6]:>10s} {r[7]:>10s} "
              f"{r[8]:>7s} {r[9]:>7s} {r[10]:>9s}")


if __name__ == "__main__":
    main()
