"""Figs 5-8: OSU-style one-/two-sided latency and bandwidth vs message size
and process count.

modeled : calibrated model across CXL SHM / TCP-Ethernet / TCP-CX6 for the
          full 1B..8MB x {2..32} procs sweep (the paper's axes), asserting
          the headline ratios.
measured: the real cMPI transports on this host (2 procs): one-sided =
          RMA window put/get, two-sided = SPSC queue send/recv, vs real
          localhost TCP.
protocol: eager (queue cells) vs staged rendezvous (sender staging
          object) vs POSTED rendezvous (receiver-posted matchbox entry,
          one copy total) vs pool-resident-source rendezvous — latency
          AND bytes copied per message as counted by ProtocolStats, the
          paper's copies-are-the-cost model. Posted rendezvous must copy
          >= 1.9x fewer bytes than staged at 1 MB (asserted).
collective: free-function allreduce (per-round staged rendezvous) vs the
          Comm-method allreduce (persistent pool-resident round buffers,
          PoolView zero-sender-copy rounds) — copied bytes per rank on
          1 MB payloads, the Comm API v2 headline.

``--smoke`` runs a CI-sized subset: the ``eager_threshold="auto"``
crossover micro-probe, the per-path copied-bytes measurement (with the
posted-vs-staged assertion), the collective comparison, the iallreduce
overlap / persistent posted-hit gates, the chunked-bandwidth gate
(schedule-level chunking must reach >= 1.3x the unchunked iallreduce
bandwidth at 8 MiB) and the RMA latency column (one-sided window put
vs two-sided queue send at small messages; put must stay within
``RMA_PUT_MAX_RATIO`` of the send, waived on sandboxed kernels) —
then gates the numbers against the checked-in budget
(``artifacts/bench/budget_copies.json``, +-10%).
``--write-budget`` regenerates the budget from the current
measurement.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

from benchmarks.common import (shm_bandwidth, shm_pingpong, tcp_pingpong,
                               write_csv)
from repro.perfmodel.interconnects import (CXL_SHM, ETHERNET_TCP,
                                           MELLANOX_TCP)

KB = 1024
MiB = 1024 * 1024

ART = Path(__file__).resolve().parent.parent / "artifacts" / "bench"
BUDGET_PATH = ART / "budget_copies.json"
SMOKE_PATH = ART / "smoke_copies.json"
BUDGET_TOL = 0.10
POSTED_MIN_RATIO = 1.9      # posted rendezvous vs staged, copied bytes
OVERLAP_MIN = 0.5           # iallreduce must hide >= 50% of the
                            # hideable latency at 1 MB (smoke gate)
PERSIST_HIT_RATE = 1.0      # persistent allreduce: every rendezvous
                            # send must hit a pre-posted entry
CHUNKED_MIN_SPEEDUP = 1.3   # chunked iallreduce bandwidth vs the
                            # unchunked schedule at 8 MiB (smoke gate)
RMA_PUT_MAX_RATIO = 1.25    # one-sided put vs two-sided send latency
                            # at small messages (smoke gate; put is pure
                            # load/store on the window, send pays the
                            # queue handshake — the paper's Fig 5 claim)
RMA_LAT_SIZES = (8, 512, 4096)

MODEL_SIZES = [1, 8, 64, 512, 4 * KB, 16 * KB, 64 * KB, 256 * KB,
               1 * MiB, 8 * MiB]
PROCS = [2, 8, 16, 32]
FABRICS = {"cxl_shm": CXL_SHM, "tcp_ethernet": ETHERNET_TCP,
           "tcp_cx6dx": MELLANOX_TCP}


def run_modeled() -> list[list]:
    rows = []
    for sided in ("onesided", "twosided"):
        for fname, ic in FABRICS.items():
            for p in PROCS:
                for s in MODEL_SIZES:
                    lat = ic.mpi_latency(s, onesided=sided == "onesided",
                                         procs=p)
                    bw = ic.mpi_bandwidth(s, p, onesided=sided == "onesided")
                    rows.append(["modeled", sided, fname, p, s,
                                 f"{lat * 1e6:.2f}", f"{bw / MiB:.0f}"])
    return rows


def run_measured_rma(sizes, iters=100) -> dict[int, float]:
    """One-sided put latency over a real shared-memory window."""
    from repro.core.runtime import run_processes

    def prog(env):
        win = env.comm.win_allocate("bw", max(sizes) + 64)
        out = {}
        for s in sizes:
            data = bytes(s)
            win.fence()
            t0 = time.perf_counter()
            for _ in range(iters):
                if env.rank == 0:
                    win.put(1, 0, data)
                    _ = win.get(1, 0, 1)
            dt = time.perf_counter() - t0
            win.fence()
            out[s] = dt / iters / 2.0
        return out

    return run_processes(2, prog, pool_bytes=128 << 20, timeout=600)[0]


def run_rma_latency(sizes=RMA_LAT_SIZES, iters: int = 120
                    ) -> dict[int, dict]:
    """Put-vs-send latency column at small messages — Fig 5's one- vs
    two-sided comparison at smoke scale, on the real transports.

    one-sided: window ``put`` + 1-byte completion ``get`` over the
    shared-memory window (``run_measured_rma``), halved to a one-way
    figure. two-sided: SPSC queue ping-pong half round trip
    (``shm_pingpong``). At small sizes the put is a pure load/store on
    the target segment (no peer progress, no handshake), so its latency
    should sit at or below the send's, which pays the queue
    enqueue/dequeue on both ends.

    Returns ``{size: {"put_us", "send_us", "ratio"}}`` with ratio =
    put/send (< 1 means one-sided wins).
    """
    put = run_measured_rma(list(sizes), iters=iters)
    send = shm_pingpong(list(sizes), iters=iters)
    out = {}
    print(f"{'size':>8} {'put_us':>10} {'send_us':>10} {'put/send':>9}")
    for s in sizes:
        pu, su = put[s] * 1e6, send[s] * 1e6
        out[s] = {"put_us": round(pu, 2), "send_us": round(su, 2),
                  "ratio": round(pu / su, 3)}
        print(f"{s:>8} {pu:>10.2f} {su:>10.2f} {pu / su:>9.2f}")
    return out


PROTOCOLS = ("eager", "rndv_staged", "rndv_posted", "rndv_poolsrc")


def run_protocols(sizes, iters=60) -> tuple[list[list], dict]:
    """Per-path one-way stream latency + copied bytes/message.

    eager         every message through queue cells (threshold = inf);
                  ~2 payload copies (user -> cell, cell -> user).
    rndv_staged   sender stages into a fresh pool object, receiver
                  drains it: ~2 payload copies + per-message arena
                  metadata traffic.
    rndv_posted   the receiver pre-posts a pool-resident destination
                  (matchbox entry); the sender writes the payload
                  straight into it: ONE payload copy, zero receiver-side
                  drain, no arena churn. The receive is posted before
                  the credit message that releases the sender, so every
                  iteration deterministically hits the entry.
    rndv_poolsrc  sender-side zero copy (PoolBuffer source), receiver
                  drains once: ONE payload copy (the PR 1 headline).

    Copied bytes come from each rank's ProtocolStats delta across the
    loop: every physical data move through the coherence protocol,
    framing headers and descriptors included. Returns (csv_rows,
    {(protocol, size): (latency_s, copied_bytes_per_msg)}) and asserts
    the posted path copies >= 1.9x fewer bytes than staged at the
    largest size.
    """
    from repro.core.runtime import run_processes

    def make_prog(protocol):
        def prog(env):
            out = {}
            for s in sizes:
                if protocol == "rndv_poolsrc" and env.rank == 0:
                    src = env.comm.alloc_buffer(s)
                    src.view()[:] = b"\xab" * s
                else:
                    src = b"\xab" * s
                if protocol == "rndv_posted" and env.rank == 1:
                    dst = env.comm.alloc_buffer(s)
                else:
                    dst = bytearray(s)
                env.comm.barrier()
                st = env.arena.view.stats
                s0 = st.snapshot()
                t0 = time.perf_counter()
                for _ in range(iters):
                    if env.rank == 0:
                        env.comm.recv(1, tag=2)      # 1-byte credit
                        env.comm.send(1, src, tag=1)
                    else:
                        # post the receive FIRST, then release the
                        # sender: posted entries exist before the
                        # sender's descriptor (matchbox contract)
                        rreq = env.comm.irecv_into(0, dst, tag=1)
                        env.comm.send(0, b"", tag=2)
                        rreq.wait()
                dt = time.perf_counter() - t0
                delta = st.delta(s0)
                env.comm.barrier()
                hits = env.comm.posted_sends
                out[s] = (dt / iters, delta["copied_bytes"], hits)
            return out
        return prog

    rows = []
    results = {}
    for protocol in PROTOCOLS:
        thresh = 1 << 40 if protocol == "eager" else 0
        res = run_processes(2, make_prog(protocol), pool_bytes=256 << 20,
                            cell_size=16384,
                            eager_threshold=thresh, timeout=600)
        for s in sizes:
            lat = res[0][s][0]
            copied = (res[0][s][1] + res[1][s][1]) / iters
            results[(protocol, s)] = (lat, copied)
            rows.append(["measured", "protocol", f"cmpi_{protocol}", 2, s,
                         f"{lat * 1e6:.2f}", f"{copied:.0f}"])
        if protocol == "rndv_posted":
            hits = res[0][max(sizes)][2]
            assert hits > 0, "posted protocol never hit a matchbox entry"
    # crossover + headline copy ratios
    cross = next((s for s in sizes
                  if results[("rndv_staged", s)][0]
                  <= results[("eager", s)][0]), None)
    print(f"eager/rendezvous latency crossover: "
          f"{cross if cross is not None else f'> {sizes[-1]}'} bytes")
    big = sizes[-1]
    staged = results[("rndv_staged", big)][1]
    posted = results[("rndv_posted", big)][1]
    ratio = staged / max(posted, 1)
    print(f"copied bytes per {big}B message: "
          f"eager {results[('eager', big)][1]:.0f}, "
          f"staged {staged:.0f}, posted {posted:.0f}, "
          f"poolsrc {results[('rndv_poolsrc', big)][1]:.0f} "
          f"-> {ratio:.2f}x fewer on posted vs staged")
    assert ratio >= POSTED_MIN_RATIO, (
        f"posted rendezvous must copy >= {POSTED_MIN_RATIO}x fewer bytes "
        f"than staged at {big}B (got {ratio:.2f}x)")
    return rows, results


def run_collectives(nbytes: int = 1 << 20, iters: int = 4,
                    procs: int = 2) -> tuple[list[list], float, float]:
    """Copied bytes per rank for a ``nbytes`` allreduce: the deprecated
    free-function path (every ring round stages into a fresh arena
    object) vs ``comm.allreduce`` (persistent pool-resident round
    buffers; each round ships a PoolView descriptor and pays exactly one
    pool->pool copy). The delta is the PR's acceptance metric."""
    from repro.core import collectives as coll
    from repro.core.runtime import run_processes

    def prog(env):
        x = np.full(nbytes // 8, float(env.rank + 1))
        # warm both paths (allocates the persistent round buffers)
        coll.allreduce(env.comm, x, algo="ring")
        env.comm.allreduce(x, algo="ring")
        st = env.arena.view.stats
        env.comm.barrier()
        s0 = st.snapshot()
        for _ in range(iters):
            r_free = coll.allreduce(env.comm, x, algo="ring")
        s1 = st.snapshot()
        env.comm.barrier()
        for _ in range(iters):
            r_meth = env.comm.allreduce(x, algo="ring")
        d_meth = st.delta(s1)
        env.comm.barrier()
        assert np.allclose(r_free, r_meth)
        free_copied = s1["copied_bytes"] - s0["copied_bytes"]
        return free_copied / iters, d_meth["copied_bytes"] / iters

    res = run_processes(procs, prog, pool_bytes=256 << 20,
                        cell_size=16384, timeout=600)
    free_b = sum(r[0] for r in res) / procs
    meth_b = sum(r[1] for r in res) / procs
    ratio = free_b / max(meth_b, 1)
    print(f"allreduce {nbytes}B x {procs} ranks, copied bytes/rank: "
          f"free-function {free_b:.0f} vs comm.allreduce {meth_b:.0f} "
          f"-> {ratio:.2f}x fewer on pool-resident round buffers")
    assert meth_b < free_b, (
        "pool-resident method collectives must copy fewer bytes than "
        "the free-function path")
    rows = [["measured", "collective", "cmpi_allreduce_free", procs,
             nbytes, "", f"{free_b:.0f}"],
            ["measured", "collective", "cmpi_allreduce_comm", procs,
             nbytes, "", f"{meth_b:.0f}"]]
    return rows, free_b, meth_b


def run_overlap(nbytes: int = 1 << 20, iters: int = 5
                ) -> tuple[list[list], float]:
    """Communication/computation overlap of ``iallreduce`` vs blocking
    allreduce (the schedule-engine headline).

    Rank 1 arrives LATE to the allreduce (a 2.5-compute-slice sleep —
    the load imbalance nonblocking collectives exist to hide; a sleep
    rather than real work so the measurement is free of CPU contention
    on small hosts); rank 0 measures:

      serial     allreduce(); compute()          — the blocking program
      overlap    iallreduce(); compute(); wait() — compute injected
                 between start and wait, ticking ``comm.progress()``

    Overlap efficiency = (t_serial - t_overlap) / t_comm — the OSU
    convention: the fraction of the blocking communication time that
    disappeared behind compute. 0 = no overlap (the i-form degenerated
    to back-to-back), 1 = the entire communication hid. With the
    pre-posted schedule receives, rank 0's payload lands via the peer
    while rank 0 computes, so efficiency approaches 1; the smoke gate
    asserts >= OVERLAP_MIN."""
    from repro.core.runtime import run_processes

    # the injected compute is a fixed WALL-CLOCK window of numpy work
    # (deadline-based): the overlap measurement then cannot be skewed
    # by BLAS thread counts or CPU contention on small hosts
    t_compute = 0.05

    def prog(env):
        c = env.comm
        x = np.full(nbytes // 8, float(env.rank + 1))
        a = np.ones((96, 96))

        def compute(progress: bool):
            end = time.perf_counter() + t_compute
            while time.perf_counter() < end:
                np.dot(a, a)
                if progress:
                    c.progress()

        c.allreduce(x, algo="rd")            # warm schedules + buffers
        out = []
        for _ in range(iters):
            c.barrier()
            if env.rank == 1:
                # arrive one compute-window late in BOTH phases: the
                # load-imbalance window rank 0 can (or cannot) hide.
                # A sleep, not work — the peer's CPUs stay free
                time.sleep(t_compute)
                c.allreduce(x, algo="rd")
                c.barrier()
                time.sleep(t_compute)
                c.allreduce(x, algo="rd")
                c.barrier()
                continue
            t0 = time.perf_counter()
            c.allreduce(x, algo="rd")
            t_comm = time.perf_counter() - t0
            compute(False)
            t_serial = time.perf_counter() - t0
            c.barrier()
            t0 = time.perf_counter()
            req = c.iallreduce(x, algo="rd")
            compute(True)
            req.wait()
            t_ov = time.perf_counter() - t0
            c.barrier()
            out.append((t_comm, t_compute, t_serial, t_ov))
        return out

    res = run_processes(2, prog, pool_bytes=256 << 20, cell_size=16384,
                        timeout=600)
    effs = []
    for t_comm, t_compute, t_serial, t_ov in res[0]:
        effs.append((t_serial - t_ov) / max(t_comm, 1e-9))
    effs.sort()
    eff = effs[len(effs) // 2]               # median: de-noise CI hosts
    t_comm, t_compute, t_serial, t_ov = res[0][0]
    print(f"iallreduce overlap @ {nbytes}B: blocking {t_serial * 1e3:.2f}"
          f" ms (comm {t_comm * 1e3:.2f} + compute {t_compute * 1e3:.2f})"
          f" vs overlapped {t_ov * 1e3:.2f} ms -> efficiency {eff:.2f}")
    rows = [["measured", "overlap", "cmpi_iallreduce", 2, nbytes,
             f"{t_ov * 1e6:.2f}", f"{eff:.2f}"]]
    return rows, eff


def run_persistent(nbytes: int = 1 << 20, rounds: int = 10
                   ) -> tuple[list[list], float, float]:
    """MPI-4 persistent allreduce (``comm.allreduce_init``): the
    round-synchronized pre-post handshake must make EVERY rendezvous
    send of every round hit a pre-posted matchbox entry — a
    deterministic 100% posted-hit rate — with zero capacity misses
    when the matchbox is sized to the schedule
    (``Comm(matchbox_slots=2 * max-receives-per-peer)``)."""
    from repro.core.runtime import run_processes

    def prog(env):
        c = env.comm
        x = np.full(nbytes // 8, float(env.rank + 1))
        req = c.allreduce_init(x, algo="rd")
        st = env.arena.view.stats
        h0, r0 = c.posted_sends, c.rndv_sends
        s0 = st.snapshot()
        for i in range(rounds):
            x[:] = float(i + env.rank + 1)
            out = req.start().wait()
            assert out[0] == 2 * i + 3, out[0]
        delta = st.delta(s0)
        hits = c.posted_sends - h0
        rndv = c.rndv_sends - r0
        copied = delta["copied_bytes"] / rounds
        req.free()
        return hits, rndv, copied, st.mb_capacity_misses

    res = run_processes(2, prog, pool_bytes=256 << 20, cell_size=16384,
                        comm_kw={"matchbox_slots": 8}, timeout=600)
    hits = sum(r[0] for r in res)
    rndv = sum(r[1] for r in res)
    copied = sum(r[2] for r in res) / len(res)
    misses = sum(r[3] for r in res)
    rate = hits / max(rndv, 1)
    print(f"persistent allreduce {nbytes}B x {rounds} rounds: "
          f"{hits}/{rndv} rendezvous sends hit pre-posted entries "
          f"(rate {rate:.2f}, {misses} capacity misses), "
          f"{copied:.0f} copied B/rank/round")
    rows = [["measured", "collective", "cmpi_allreduce_persistent", 2,
             nbytes, "", f"{copied:.0f}"]]
    return rows, rate, copied


def yield_cost_us(reps: int = 3000, samples: int = 5) -> float:
    """Cost of one cooperative yield (``time.sleep(0)``) on this host:
    the MAX of ``samples`` averages over ``reps`` calls each. The
    progress engine spin-waits on it, so it bounds the engine's tick
    rate. On real kernels a 3000-call average stays ~0.5-3 us even
    under load; inside syscall-intercepting sandboxes (gVisor and
    friends) it swings 5-100 us — the max-of-samples catches the
    sandbox even in its calm phases, which is what multiplies every
    per-chunk round-trip and makes wall-clock pipelining measurements
    meaningless there."""
    out = []
    for _ in range(samples):
        t0 = time.perf_counter()
        for _ in range(reps):
            time.sleep(0)
        out.append((time.perf_counter() - t0) / reps * 1e6)
    return max(out)


SANDBOX_YIELD_US = 10.0     # above this, timing gates are waived


def run_chunked(nbytes: int = 8 * MiB, iters: int = 9
                ) -> tuple[list[list], float]:
    """Schedule-level chunking: large-payload iallreduce bandwidth,
    message-granular vs chunk-granular (``chunk_bytes="auto"``).

    Unchunked, each ring round is one monolithic transfer: the whole
    payload is written, then the whole payload is reduced — every
    stage streams 8 MiB through the cache hierarchy. Chunked, round
    k+1's receives for chunk c are in flight while round k still
    reduces chunk c+1, AND every write/reduce stage works in
    chunk-sized, cache-resident tiles (measured on this host:
    reducing 8 MiB as 8x1 MiB tiles is ~2.5x faster than one
    monolithic pass). The two variants are timed INTERLEAVED — an
    unchunked/chunked pair per iteration, speedup = median of the
    per-pair ratios of the slowest rank's time — so drifting host
    throughput hits both equally. The smoke gate asserts
    >= CHUNKED_MIN_SPEEDUP x."""
    from repro.core.runtime import run_processes

    def prog(env):
        c = env.comm
        x = np.full(nbytes // 8, float(env.rank + 1))
        ref = c.iallreduce(x, algo="ring").wait(None)        # warm
        chk = c.iallreduce(x, algo="ring",
                           chunk_bytes="auto").wait(None)
        assert np.allclose(ref, chk)     # chunking is a pure re-cut
        pairs = []
        for _ in range(iters):
            c.barrier()
            t0 = time.perf_counter()
            c.iallreduce(x, algo="ring").wait(None)
            tu = time.perf_counter() - t0
            c.barrier()
            t0 = time.perf_counter()
            c.iallreduce(x, algo="ring", chunk_bytes="auto").wait(None)
            pairs.append((tu, time.perf_counter() - t0))
        return pairs

    res = run_processes(2, prog, pool_bytes=512 << 20, cell_size=16384,
                        timeout=600)
    n_pairs = len(res[0])
    tus = sorted(max(r[i][0] for r in res) for i in range(n_pairs))
    tcs = sorted(max(r[i][1] for r in res) for i in range(n_pairs))
    ratios = sorted(max(r[i][0] for r in res) / max(r[i][1] for r in res)
                    for i in range(n_pairs))
    t_un, t_ch = tus[n_pairs // 2], tcs[n_pairs // 2]
    speedup = ratios[n_pairs // 2]
    bw_un, bw_ch = nbytes / t_un / MiB, nbytes / t_ch / MiB
    print(f"chunked iallreduce @ {nbytes}B: unchunked {bw_un:.0f} MiB/s "
          f"vs chunked {bw_ch:.0f} MiB/s -> {speedup:.2f}x "
          f"(median of {n_pairs} interleaved pairs)")
    rows = [["measured", "chunked", "cmpi_iallreduce_unchunked", 2,
             nbytes, f"{t_un * 1e6:.2f}", f"{bw_un:.0f}"],
            ["measured", "chunked", "cmpi_iallreduce_chunked", 2,
             nbytes, f"{t_ch * 1e6:.2f}", f"{bw_ch:.0f}"]]
    return rows, speedup


TUNED_MIN_RATIO = 0.9       # tuned iallreduce must not be slower than
#                             the heuristic baseline beyond 10% noise


def run_tuned(nbytes: int = 8 * MiB, iters: int = 7
              ) -> tuple[list[list], float]:
    """Machine-profile autotuning gate: the same 8 MiB chunked ring
    iallreduce on an untuned comm (heuristic policies: fixed ÷8 chunk
    rule, default matchbox depth) vs a ``Comm(tuning="auto")`` that
    consumed ``artifacts/bench/machine_profile.json`` (knee-derived
    chunk size, measured crossover, measured matchbox depth). A missing
    or stale profile is generated on the spot with a smoke sweep.
    Timed interleaved like ``run_chunked`` — an untuned/tuned pair per
    iteration, ratio = median of per-pair slowest-rank ratios — and
    gated at >= TUNED_MIN_RATIO (tuned must never lose more than
    noise; on a quiet host it should win)."""
    from repro.core import profile as _profile
    from repro.core.comm import Comm
    from repro.core.runtime import run_processes

    if _profile.load_profile(quiet=True) is None:
        from benchmarks.roofline import write_machine_profile
        print("no fresh machine profile — running a smoke sweep first")
        write_machine_profile(smoke=True)

    def prog(env):
        c = env.comm                     # untuned: heuristic policies
        tuned = Comm(env.arena, env.rank, env.size, cell_size=16384,
                     n_cells=8, tuning="auto", name="tuned")
        assert tuned._tuned is not None, \
            "tuning='auto' failed to consume the machine profile"
        x = np.full(nbytes // 8, float(env.rank + 1))
        ref = c.iallreduce(x, algo="ring",
                           chunk_bytes="auto").wait(None)       # warm
        chk = tuned.iallreduce(x, algo="ring",
                               chunk_bytes="auto").wait(None)
        assert np.allclose(ref, chk)     # tuning only re-cuts the wire
        pairs = []
        for _ in range(iters):
            c.barrier()
            t0 = time.perf_counter()
            c.iallreduce(x, algo="ring", chunk_bytes="auto").wait(None)
            tu = time.perf_counter() - t0
            tuned.barrier()
            t0 = time.perf_counter()
            tuned.iallreduce(x, algo="ring",
                             chunk_bytes="auto").wait(None)
            pairs.append((tu, time.perf_counter() - t0))
        cb = tuned._tuned["chunk_floor"]
        tuned.free()
        return pairs, cb

    res = run_processes(2, prog, pool_bytes=512 << 20,
                        cell_size=16384, timeout=600)
    pairs = [r[0] for r in res]
    chunk_floor = res[0][1]
    n_pairs = len(pairs[0])
    tus = sorted(max(p[i][0] for p in pairs) for i in range(n_pairs))
    tts = sorted(max(p[i][1] for p in pairs) for i in range(n_pairs))
    ratios = sorted(max(p[i][0] for p in pairs)
                    / max(p[i][1] for p in pairs) for i in range(n_pairs))
    t_un, t_td = tus[n_pairs // 2], tts[n_pairs // 2]
    ratio = ratios[n_pairs // 2]
    bw_un, bw_td = nbytes / t_un / MiB, nbytes / t_td / MiB
    ch = ("unchunked" if chunk_floor == 0
          else f"chunk {chunk_floor // 1024} KiB")
    print(f"tuned iallreduce @ {nbytes}B: heuristic {bw_un:.0f} MiB/s "
          f"vs profile-tuned {bw_td:.0f} MiB/s -> {ratio:.2f}x "
          f"(tuned {ch}, median of {n_pairs} interleaved pairs)")
    rows = [["measured", "tuned", "cmpi_iallreduce_heuristic", 2,
             nbytes, f"{t_un * 1e6:.2f}", f"{bw_un:.0f}"],
            ["measured", "tuned", "cmpi_iallreduce_profile", 2,
             nbytes, f"{t_td * 1e6:.2f}", f"{bw_td:.0f}"]]
    return rows, ratio


TRACE_OVERHEAD_MAX_PCT = 5.0   # tracing-disabled cost vs the 8 MiB
#                                iallreduce smoke baseline (PR-8 level)
TRACE_DIR = ART / "trace"


def run_trace(out_dir: Path | None = None, nbytes: int = 8 * MiB) -> list:
    """Traced 2-process smoke: a chunked ring iallreduce, a posted-
    rendezvous pt2pt exchange and a notified-put RMA epoch, each rank
    recording into its flight-recorder ring (``trace=True``) and
    dumping ``fig5_rank{r}.json``. Asserts the merged Chrome trace
    spans >= 8 distinct event types across the pt2pt / sched /
    matchbox / RMA lanes (the observability acceptance bar), prints
    the cross-rank summary, and returns the dump paths for
    ``python -m repro.trace merge``."""
    from repro.core.runtime import run_processes
    from repro.core.trace import load_dump, merge_dumps, summarize_dumps
    out_dir = TRACE_DIR if out_dir is None else Path(out_dir)

    def prog(env):
        c = env.comm
        x = np.full(nbytes // 8, float(env.rank + 1))
        c.iallreduce(x, algo="ring", chunk_bytes="auto").wait(None)
        # posted-rendezvous pt2pt: receive up before the sender releases
        if env.rank == 0:
            c.recv(1, tag=2)
            c.send(1, b"\xab" * MiB, tag=1)
        else:
            dst = c.alloc_buffer(MiB)
            rreq = c.irecv_into(0, dst, tag=1)
            c.send(0, b"", tag=2)
            rreq.wait()
            dst.free()
        # RMA: passive epoch + notified put + collective fence
        w = c.win_allocate("trace_w", 8192)
        w.lock_all()
        if env.rank == 0:
            w.put_notify(1, 0, b"\xcd" * 4096)
        else:
            w.wait_notify(0)
        w.unlock_all()
        w.fence()
        w.free()
        return c.trace_dump(out_dir / f"fig5_rank{env.rank}.json")

    paths = run_processes(2, prog, pool_bytes=512 << 20, cell_size=16384,
                          comm_kw={"trace": True}, timeout=600)
    dumps = [load_dump(p) for p in paths]
    merged = merge_dumps(dumps)
    names = {e["name"] for e in merged["traceEvents"] if e["ph"] != "M"}
    kinds = set()
    for d in dumps:
        kinds.update(d["report"]["counters"])
    assert len(kinds) >= 8, (
        f"traced smoke produced only {len(kinds)} distinct event types "
        f"({sorted(kinds)}); expected >= 8 spanning pt2pt/sched/"
        f"matchbox/RMA")
    print(summarize_dumps(dumps))
    print(f"{len(kinds)} distinct event types, "
          f"{len(names)} timeline slice names; per-rank dumps:")
    for p in paths:
        print(f"  {p}")
    print(f"merge with: python -m repro.trace merge "
          f"{' '.join(str(p) for p in paths)} "
          f"-o {out_dir / 'fig5_timeline.json'}")
    return paths


def run_trace_overhead(nbytes: int = 8 * MiB, iters: int = 5
                       ) -> tuple[float, dict]:
    """Disabled-tracing overhead bound vs the 8 MiB iallreduce smoke
    baseline.

    The PR that introduced the flight recorder cannot rerun its
    predecessor, so the bound is computed, not A/B-timed: (number of
    emit-site firings one ENABLED 8 MiB chunked iallreduce records) x
    (microbenched cost of one disabled-site predicate check) / (the
    measured DISABLED iallreduce wall time). Every instrumentation
    site costs exactly one attribute load + branch when tracing is
    off (LP005 enforces the shape), so the product bounds what the
    default-off recorder adds to the PR-8 baseline."""
    from repro.core.comm import Comm
    from repro.core.runtime import run_processes
    from repro.core.trace import Tracer

    def prog(env):
        c = env.comm                       # tracing disabled (default)
        x = np.full(nbytes // 8, float(env.rank + 1))
        c.iallreduce(x, algo="ring", chunk_bytes="auto").wait(None)
        ts = []
        for _ in range(iters):
            c.barrier()
            t0 = time.perf_counter()
            c.iallreduce(x, algo="ring", chunk_bytes="auto").wait(None)
            ts.append(time.perf_counter() - t0)
        traced = Comm(env.arena, env.rank, env.size, cell_size=16384,
                      n_cells=8, trace=True, name="trov")
        traced.iallreduce(x, algo="ring", chunk_bytes="auto").wait(None)
        emits = traced.tracer.recorded
        traced.free()
        ts.sort()
        return ts[len(ts) // 2], emits

    res = run_processes(2, prog, pool_bytes=512 << 20, cell_size=16384,
                        timeout=600)
    t_coll = max(r[0] for r in res)
    emits = max(r[1] for r in res)
    # one disabled site: attribute load + falsy branch
    tr = Tracer(capacity=1, enabled=False)
    reps = 200_000
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(reps):
            if tr.enabled:
                raise AssertionError
        best = min(best, (time.perf_counter() - t0) / reps)
    check_ns = best * 1e9
    pct = emits * check_ns / (t_coll * 1e9) * 100.0
    detail = {"emit_sites_fired": emits,
              "predicate_check_ns": round(check_ns, 2),
              "iallreduce_8mib_s": round(t_coll, 6)}
    print(f"trace overhead bound: {emits} sites x {check_ns:.1f} ns "
          f"predicate / {t_coll * 1e3:.1f} ms iallreduce = {pct:.3f}% "
          f"(gate <= {TRACE_OVERHEAD_MAX_PCT}%)")
    return pct, detail


def run_crossover_probe(procs: int = 2) -> None:
    """Exercise ``eager_threshold='auto'``: every rank runs the one-shot
    init-time micro-probe and reports its measured crossover."""
    from repro.core.runtime import run_processes

    def prog(env):
        env.comm.send(1 - env.rank, b"x" * 100_000, tag=1)
        data, _ = env.comm.recv(1 - env.rank, tag=1)
        assert len(data) == 100_000
        return (env.comm.eager_threshold, env.comm.probed_crossover,
                env.comm.probe_mode)

    res = run_processes(procs, prog, pool_bytes=64 << 20,
                        eager_threshold="auto", timeout=300)
    for r, (thr, cross, mode) in enumerate(res):
        print(f"rank {r}: auto eager_threshold={thr}B via {mode} probe "
              f"(measured rendezvous crossover: "
              f"{cross if cross is not None else 'beyond probe range'})")


def run(quick: bool = False) -> list[list]:
    rows = run_modeled()
    sizes = [8, 512, 4 * KB, 64 * KB] if quick else \
        [8, 64, 512, 4 * KB, 16 * KB, 64 * KB, 256 * KB]
    iters = 30 if quick else 150
    shm_lat = shm_pingpong(sizes, iters=iters)
    tcp_lat = tcp_pingpong(sizes, iters=iters)
    rma_lat = run_measured_rma(sizes, iters=iters)
    shm_bw = shm_bandwidth(sizes, iters=max(iters // 10, 5))
    for s in sizes:
        rows.append(["measured", "twosided", "host_shm_cmpi", 2, s,
                     f"{shm_lat[s] * 1e6:.2f}",
                     f"{shm_bw[s] / MiB:.0f}"])
        rows.append(["measured", "onesided", "host_shm_rma", 2, s,
                     f"{rma_lat[s] * 1e6:.2f}", ""])
        rows.append(["measured", "twosided", "host_tcp_localhost", 2, s,
                     f"{tcp_lat[s] * 1e6:.2f}", ""])
    proto_sizes = [64 * KB, 1 * MiB] if quick else \
        [16 * KB, 64 * KB, 256 * KB, 1 * MiB]
    proto_rows, _ = run_protocols(proto_sizes, iters=20 if quick else 60)
    rows += proto_rows
    if not quick:
        # quick mode skips these: CI runs them via --smoke in the next
        # step
        rows += run_collectives(iters=4)[0]
        rows += run_persistent()[0]
        rows += run_overlap()[0]
        rows += run_chunked()[0]
        rows += run_tuned()[0]
    write_csv("fig5_8_osu",
              ["kind", "sided", "fabric", "procs", "msg_bytes",
               "latency_us", "bandwidth_MiB_s_or_copied_B"], rows)
    return rows


def main(quick: bool = False) -> None:
    rows = run(quick)
    # headline summary
    import collections
    d = {(r[0], r[1], r[2], r[3], r[4]): r for r in rows}
    cxl16k = float(d[("modeled", "onesided", "cxl_shm", 16, 16 * KB)][6])
    eth16k = float(d[("modeled", "onesided", "tcp_ethernet", 16, 16 * KB)][6])
    print(f"modeled one-sided 16KB/16p: CXL {cxl16k:.0f} MiB/s vs "
          f"TCP-Eth {eth16k:.0f} -> {cxl16k / eth16k:.0f}x "
          f"(paper: up to 71.6x)")
    meas = [r for r in rows if r[0] == "measured"]
    print(f"{len(meas)} measured rows (see artifacts/bench/fig5_8_osu.csv)")


# --------------------------------------------------------------------------
# copied-bytes regression gate (CI bench-gate job)
# --------------------------------------------------------------------------

def check_budget(measured: dict, budget: dict,
                 tol: float = BUDGET_TOL) -> list[str]:
    """Compare measured copied-bytes-per-message against the checked-in
    budget. Returns human-readable violations: a REGRESSION when a path
    copies more than budget*(1+tol), a STALE BUDGET when it copies less
    than budget*(1-tol) (refresh with --write-budget so future
    regressions are caught against the improved number)."""
    problems = []
    for key, ref in budget.items():
        got = measured.get(key)
        if got is None:
            problems.append(f"MISSING: {key} not measured")
            continue
        if got > ref * (1 + tol):
            problems.append(
                f"REGRESSION: {key} copies {got:.0f}B/msg, budget "
                f"{ref:.0f}B (+{(got / ref - 1) * 100:.1f}% > "
                f"+{tol * 100:.0f}%)")
        elif got < ref * (1 - tol):
            problems.append(
                f"STALE BUDGET: {key} copies {got:.0f}B/msg, budget "
                f"{ref:.0f}B ({(got / ref - 1) * 100:.1f}% < "
                f"-{tol * 100:.0f}%) — rerun with --write-budget")
    for key in measured:
        if key not in budget:
            problems.append(f"UNBUDGETED: {key} measured but not in "
                            f"budget — rerun with --write-budget")
    return problems


def run_budget_gate(write_budget: bool = False) -> None:
    """Measure copied bytes/message on every protocol path plus the
    collective trio (free-function / comm-method / persistent), the
    schedule-engine quality gates (iallreduce overlap efficiency,
    persistent posted-hit rate) AND the RMA put-vs-send latency column,
    record everything (artifacts/bench/smoke_copies.json), and gate
    against the checked-in budget."""
    _, proto = run_protocols([1 * MiB], iters=6)
    rows, free_b, meth_b = run_collectives(iters=2)
    _, hit_rate, persist_b = run_persistent()
    _, overlap_eff = run_overlap()
    _, chunked_speedup = run_chunked()
    _, tuned_ratio = run_tuned()
    rma_lat = run_rma_latency()
    worst_rma_ratio = max(v["ratio"] for v in rma_lat.values())
    trace_pct, trace_detail = run_trace_overhead()
    measured = {f"pt2pt_{p}@1MiB": proto[(p, 1 * MiB)][1]
                for p in PROTOCOLS}
    measured["collective_allreduce_free@1MiB_2p"] = free_b
    measured["collective_allreduce_comm@1MiB_2p"] = meth_b
    measured["collective_allreduce_persistent@1MiB_2p"] = persist_b
    gates = {
        "overlap_efficiency@1MiB_2p": round(overlap_eff, 3),
        "persistent_posted_hit_rate@1MiB_2p": round(hit_rate, 3),
        "chunked_iallreduce_speedup@8MiB_2p": round(chunked_speedup, 3),
        "tuned_iallreduce_ratio@8MiB_2p": round(tuned_ratio, 3),
        "trace_disabled_overhead_pct@8MiB_2p": round(trace_pct, 4),
    }
    yc = yield_cost_us()
    ART.mkdir(parents=True, exist_ok=True)
    SMOKE_PATH.write_text(json.dumps(
        {"copied_bytes_per_message": {k: round(v, 1)
                                      for k, v in measured.items()},
         "quality_gates": gates,
         # latency column, not a copy budget: put/send wall-clock is
         # host-dependent, so it is recorded for inspection and gated
         # only by the ratio floor below (sandbox-waived), never by
         # the +-10% copied-bytes band
         "rma_latency_us": {str(s): v for s, v in rma_lat.items()},
         # bound inputs for trace_disabled_overhead_pct@8MiB_2p: the
         # flight recorder's default-off cost is (sites fired when
         # enabled) x (disabled predicate-check ns) / iallreduce time
         "trace_overhead_detail": trace_detail,
         "host_yield_cost_us": round(yc, 2)},
        indent=2) + "\n")
    print(f"measured copy/overlap profile written to {SMOKE_PATH}")
    # hard gates (not tolerance-banded): overlap is a floor, the
    # persistent hit rate is exact by construction. The thresholds live
    # in the checked-in budget's quality_gates section (the same
    # maintainer workflow as the copy budgets); the module constants
    # are the write-budget defaults and the fallback
    if not write_budget:
        # gate mode only: --write-budget must stay usable on a host
        # that transiently misses the timing-dependent overlap floor
        # (the copied-bytes numbers being refreshed are deterministic)
        overlap_min, hit_min = OVERLAP_MIN, PERSIST_HIT_RATE
        chunked_min, tuned_min = CHUNKED_MIN_SPEEDUP, TUNED_MIN_RATIO
        rma_max = RMA_PUT_MAX_RATIO
        trace_max = TRACE_OVERHEAD_MAX_PCT
        if BUDGET_PATH.exists():
            qg = json.loads(BUDGET_PATH.read_text()).get(
                "quality_gates", {})
            overlap_min = qg.get("overlap_efficiency_min@1MiB_2p",
                                 overlap_min)
            hit_min = qg.get("persistent_posted_hit_rate@1MiB_2p",
                             hit_min)
            chunked_min = qg.get(
                "chunked_iallreduce_speedup_min@8MiB_2p", chunked_min)
            tuned_min = qg.get(
                "tuned_iallreduce_min_ratio@8MiB_2p", tuned_min)
            rma_max = qg.get("rma_put_vs_send_max_ratio@small",
                             rma_max)
            trace_max = qg.get("trace_disabled_overhead_max_pct",
                               trace_max)
        assert hit_rate >= hit_min, (
            f"persistent allreduce posted-hit rate {hit_rate:.2f} < "
            f"{hit_min} — the round-synchronized pre-post handshake "
            f"regressed")
        assert overlap_eff >= overlap_min, (
            f"iallreduce overlap efficiency {overlap_eff:.2f} < "
            f"{overlap_min} at 1 MiB — the schedule engine is not "
            f"overlapping compute")
        chunk_note = (f"chunked speedup {chunked_speedup:.2f}x >= "
                      f"{chunked_min}x")
        tuned_note = (f"tuned ratio {tuned_ratio:.2f}x >= {tuned_min}x")
        rma_note = (f"rma put/send {worst_rma_ratio:.2f} <= {rma_max}")
        trace_note = (f"trace-off overhead {trace_pct:.3f}% <= "
                      f"{trace_max}%")
        if yc > SANDBOX_YIELD_US:
            # syscall-intercepting sandbox (gVisor-class): every
            # cooperative yield costs 100x a real kernel's, so per-chunk
            # engine round-trips dominate any wall-clock pipelining
            # measurement. The speedup is still measured and recorded;
            # the floor is only enforced where timing means something.
            print(f"WARNING: sandboxed kernel detected (sched-yield "
                  f"{yc:.0f} us > {SANDBOX_YIELD_US:.0f} us) — chunked "
                  f"speedup gate ({chunked_min}x) waived on this host; "
                  f"measured {chunked_speedup:.2f}x")
            chunk_note = (f"chunked speedup {chunked_speedup:.2f}x "
                          f"(gate waived: sandboxed kernel)")
            print(f"WARNING: sandboxed kernel detected — tuned-vs-"
                  f"untuned gate ({tuned_min}x) waived on this host; "
                  f"measured {tuned_ratio:.2f}x")
            tuned_note = (f"tuned ratio {tuned_ratio:.2f}x "
                          f"(gate waived: sandboxed kernel)")
            # the send side of the put-vs-send column spin-waits on
            # the queue, so the same yield-cost multiplier distorts it
            print(f"WARNING: sandboxed kernel detected — rma put-vs-"
                  f"send latency gate ({rma_max}) waived on this "
                  f"host; measured worst ratio {worst_rma_ratio:.2f}")
            rma_note = (f"rma put/send {worst_rma_ratio:.2f} "
                        f"(gate waived: sandboxed kernel)")
            # the overhead bound's denominator is the same
            # yield-dominated iallreduce wall time, so the ratio is
            # meaningless here; measurement stays in the smoke JSON
            print(f"WARNING: sandboxed kernel detected — trace-"
                  f"disabled overhead gate ({trace_max}%) waived on "
                  f"this host; measured {trace_pct:.3f}%")
            trace_note = (f"trace-off overhead {trace_pct:.3f}% "
                          f"(gate waived: sandboxed kernel)")
        else:
            from repro.core.profile import load_profile
            prof = load_profile(quiet=True)
            if chunked_speedup < chunked_min and prof is not None \
                    and prof.best_chunk == 0:
                # the profile's own end-to-end sweep measured unchunked
                # as fastest here: the gate's premise (chunking pays
                # for itself on real kernels) does not hold on this
                # host's memory/engine cost ratio, and the heuristic
                # always-chunk policy is itself the regression — the
                # tuned gate below enforces that tuning="auto" recovers
                # it. Waive loudly, keep the measurement.
                print(f"WARNING: machine profile measured unchunked as "
                      f"fastest (best_chunk_bytes=0) — chunked speedup "
                      f"gate ({chunked_min}x) waived on this host; "
                      f"measured {chunked_speedup:.2f}x; the tuned "
                      f"gate enforces recovery via tuning='auto'")
                chunk_note = (f"chunked speedup {chunked_speedup:.2f}x "
                              f"(gate waived: profile measured "
                              f"unchunked fastest)")
            else:
                assert chunked_speedup >= chunked_min, (
                    f"chunked iallreduce speedup {chunked_speedup:.2f}x"
                    f" < {chunked_min}x at 8 MiB — schedule-level "
                    f"chunking is not pipelining")
            assert tuned_ratio >= tuned_min, (
                f"profile-tuned iallreduce is {tuned_ratio:.2f}x the "
                f"heuristic baseline < {tuned_min}x at 8 MiB — the "
                f"machine profile is mis-tuning the comm core")
            assert worst_rma_ratio <= rma_max, (
                f"one-sided put latency is {worst_rma_ratio:.2f}x the "
                f"two-sided send at small messages (> {rma_max}x) — "
                f"the RMA fast path regressed vs the queue handshake")
            assert trace_pct <= trace_max, (
                f"tracing-disabled overhead bound {trace_pct:.3f}% > "
                f"{trace_max}% of the 8 MiB iallreduce — the flight "
                f"recorder's off-path predicate checks are no longer "
                f"free; check LP005 and the emit-site count")
    if write_budget:
        BUDGET_PATH.write_text(json.dumps({
            "_comment": ("copied-bytes-per-message budget for the CI "
                         "bench-gate job; regenerate with "
                         "`python -m benchmarks.fig5_8_osu --smoke "
                         "--write-budget`"),
            "tolerance": BUDGET_TOL,
            "copied_bytes_per_message": {k: round(v, 1)
                                         for k, v in measured.items()},
            "quality_gates": {
                "overlap_efficiency_min@1MiB_2p": OVERLAP_MIN,
                "persistent_posted_hit_rate@1MiB_2p": PERSIST_HIT_RATE,
                "chunked_iallreduce_speedup_min@8MiB_2p":
                    CHUNKED_MIN_SPEEDUP,
                "tuned_iallreduce_min_ratio@8MiB_2p": TUNED_MIN_RATIO,
                "rma_put_vs_send_max_ratio@small": RMA_PUT_MAX_RATIO,
                "trace_disabled_overhead_max_pct":
                    TRACE_OVERHEAD_MAX_PCT,
            },
        }, indent=2) + "\n")
        print(f"budget written to {BUDGET_PATH}")
        return
    if not BUDGET_PATH.exists():
        sys.exit(f"no budget at {BUDGET_PATH}; generate one with "
                 f"`python -m benchmarks.fig5_8_osu --smoke "
                 f"--write-budget` and commit it")
    budget = json.loads(BUDGET_PATH.read_text())
    tol = budget.get("tolerance", BUDGET_TOL)
    problems = check_budget(measured,
                            budget["copied_bytes_per_message"], tol)
    if problems:
        print("copied-bytes budget gate FAILED:")
        for p in problems:
            print(f"  {p}")
        sys.exit(1)
    print(f"copied-bytes budget gate OK "
          f"({len(measured)} paths within +-{tol * 100:.0f}%; overlap "
          f"{overlap_eff:.2f} >= {overlap_min}, posted-hit rate "
          f"{hit_rate:.2f}, {chunk_note}, {tuned_note}, {rma_note}, "
          f"{trace_note})")


def smoke(write_budget: bool = False) -> None:
    """CI-sized subset: the auto-threshold crossover probe, the
    per-path copied-bytes measurement (posted-vs-staged assertion
    included), the iallreduce overlap gate and the persistent
    allreduce posted-hit gate — all against the checked-in budget."""
    run_crossover_probe()
    run_budget_gate(write_budget=write_budget)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: crossover probe + per-path copied "
                         "bytes, gated against the checked-in budget")
    ap.add_argument("--write-budget", action="store_true",
                    help="with --smoke: regenerate "
                         "artifacts/bench/budget_copies.json instead of "
                         "gating against it")
    ap.add_argument("--trace", action="store_true",
                    help="run the traced 2-process smoke and write "
                         "per-rank flight-recorder dumps to "
                         "artifacts/bench/trace/ for "
                         "`python -m repro.trace merge`")
    args = ap.parse_args()
    if args.trace:
        run_trace()
    elif args.smoke or args.write_budget:
        smoke(write_budget=args.write_budget)
    else:
        main(quick=args.quick)
