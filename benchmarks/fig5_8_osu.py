"""Figs 5-8: OSU-style one-/two-sided latency and bandwidth vs message size
and process count.

modeled : calibrated model across CXL SHM / TCP-Ethernet / TCP-CX6 for the
          full 1B..8MB x {2..32} procs sweep (the paper's axes), asserting
          the headline ratios.
measured: the real cMPI transports on this host (2 procs): one-sided =
          RMA window put/get, two-sided = SPSC queue send/recv, vs real
          localhost TCP.
protocol: eager (queue cells) vs rendezvous (pool-resident staging /
          PoolBuffer zero-copy sends) crossover — latency AND bytes
          copied per message as counted by ProtocolStats, the paper's
          copies-are-the-cost model.
collective: free-function allreduce (per-round staged rendezvous) vs the
          Comm-method allreduce (persistent pool-resident round buffers,
          PoolView zero-sender-copy rounds) — copied bytes per rank on
          1 MB payloads, the Comm API v2 headline.

``--smoke`` runs a CI-sized subset: the ``eager_threshold="auto"``
crossover micro-probe plus the collective copied-bytes comparison.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import (shm_bandwidth, shm_pingpong, tcp_pingpong,
                               write_csv)
from repro.perfmodel.interconnects import (CXL_SHM, ETHERNET_TCP,
                                           MELLANOX_TCP)

KB = 1024
MiB = 1024 * 1024

MODEL_SIZES = [1, 8, 64, 512, 4 * KB, 16 * KB, 64 * KB, 256 * KB,
               1 * MiB, 8 * MiB]
PROCS = [2, 8, 16, 32]
FABRICS = {"cxl_shm": CXL_SHM, "tcp_ethernet": ETHERNET_TCP,
           "tcp_cx6dx": MELLANOX_TCP}


def run_modeled() -> list[list]:
    rows = []
    for sided in ("onesided", "twosided"):
        for fname, ic in FABRICS.items():
            for p in PROCS:
                for s in MODEL_SIZES:
                    lat = ic.mpi_latency(s, onesided=sided == "onesided",
                                         procs=p)
                    bw = ic.mpi_bandwidth(s, p, onesided=sided == "onesided")
                    rows.append(["modeled", sided, fname, p, s,
                                 f"{lat * 1e6:.2f}", f"{bw / MiB:.0f}"])
    return rows


def run_measured_rma(sizes, iters=100) -> dict[int, float]:
    """One-sided put latency over a real shared-memory window."""
    from repro.core.runtime import run_processes

    def prog(env):
        win = env.comm.win_allocate("bw", max(sizes) + 64)
        out = {}
        for s in sizes:
            data = bytes(s)
            win.fence()
            t0 = time.perf_counter()
            for _ in range(iters):
                if env.rank == 0:
                    win.put(1, 0, data)
                    _ = win.get(1, 0, 1)
            dt = time.perf_counter() - t0
            win.fence()
            out[s] = dt / iters / 2.0
        return out

    return run_processes(2, prog, pool_bytes=128 << 20, timeout=600)[0]


def run_protocols(sizes, iters=60) -> list[list]:
    """Eager vs rendezvous: one-way stream latency + copied bytes/message.

    eager      forces every message through queue cells (threshold = inf);
    rendezvous sends from a PoolBuffer (pool-resident source, zero
               sender-side copies; receiver bulk read_acquire_into).
    Copied bytes come from each rank's ProtocolStats delta across the
    loop: every physical data move through the coherence protocol,
    framing headers and descriptors included (the PoolBuffer path does
    no per-message arena metadata traffic, so its delta is essentially
    pure payload + one descriptor per message).
    """
    from repro.core.runtime import run_processes

    def make_prog(protocol):
        def prog(env):
            out = {}
            for s in sizes:
                dst = bytearray(s)
                if protocol == "rendezvous" and env.rank == 0:
                    src = env.comm.alloc_buffer(s)
                    src.view()[:] = b"\xab" * s
                else:
                    src = b"\xab" * s
                env.comm.barrier()
                st = env.arena.view.stats
                c0 = st.copied_bytes
                t0 = time.perf_counter()
                for _ in range(iters):
                    if env.rank == 0:
                        env.comm.send(1, src, tag=1)
                        env.comm.recv(1, tag=2)      # 1-byte credit
                    else:
                        env.comm.recv_into(0, dst, tag=1)
                        env.comm.send(0, b"", tag=2)
                dt = time.perf_counter() - t0
                c1 = st.copied_bytes
                env.comm.barrier()
                out[s] = (dt / iters, c1 - c0)
            return out
        return prog

    rows = []
    results = {}
    for protocol, thresh in (("eager", 1 << 40), ("rendezvous", 0)):
        res = run_processes(2, make_prog(protocol), pool_bytes=256 << 20,
                            cell_size=16384,
                            eager_threshold=thresh, timeout=600)
        for s in sizes:
            lat = res[0][s][0]
            copied = (res[0][s][1] + res[1][s][1]) / iters
            results[(protocol, s)] = (lat, copied)
            rows.append(["measured", "protocol", f"cmpi_{protocol}", 2, s,
                         f"{lat * 1e6:.2f}", f"{copied:.0f}"])
    # crossover + headline copy ratio
    cross = next((s for s in sizes
                  if results[("rendezvous", s)][0]
                  <= results[("eager", s)][0]), None)
    print(f"eager/rendezvous latency crossover: "
          f"{cross if cross is not None else f'> {sizes[-1]}'} bytes")
    big = sizes[-1]
    ratio = (results[("eager", big)][1]
             / max(results[("rendezvous", big)][1], 1))
    print(f"copied bytes per {big}B message: "
          f"eager {results[('eager', big)][1]:.0f} vs "
          f"rendezvous {results[('rendezvous', big)][1]:.0f} "
          f"-> {ratio:.2f}x fewer on rendezvous")
    return rows


def run_collectives(nbytes: int = 1 << 20, iters: int = 4,
                    procs: int = 2) -> list[list]:
    """Copied bytes per rank for a ``nbytes`` allreduce: the deprecated
    free-function path (every ring round stages into a fresh arena
    object) vs ``comm.allreduce`` (persistent pool-resident round
    buffers; each round ships a PoolView descriptor and pays exactly one
    pool->pool copy). The delta is the PR's acceptance metric."""
    from repro.core import collectives as coll
    from repro.core.runtime import run_processes

    def prog(env):
        x = np.full(nbytes // 8, float(env.rank + 1))
        # warm both paths (allocates the persistent round buffers)
        coll.allreduce(env.comm, x, algo="ring")
        env.comm.allreduce(x, algo="ring")
        st = env.arena.view.stats
        env.comm.barrier()
        c0 = st.copied_bytes
        for _ in range(iters):
            r_free = coll.allreduce(env.comm, x, algo="ring")
        c1 = st.copied_bytes
        env.comm.barrier()
        for _ in range(iters):
            r_meth = env.comm.allreduce(x, algo="ring")
        c2 = st.copied_bytes
        env.comm.barrier()
        assert np.allclose(r_free, r_meth)
        return (c1 - c0) / iters, (c2 - c1) / iters

    res = run_processes(procs, prog, pool_bytes=256 << 20,
                        cell_size=16384, timeout=600)
    free_b = sum(r[0] for r in res) / procs
    meth_b = sum(r[1] for r in res) / procs
    ratio = free_b / max(meth_b, 1)
    print(f"allreduce {nbytes}B x {procs} ranks, copied bytes/rank: "
          f"free-function {free_b:.0f} vs comm.allreduce {meth_b:.0f} "
          f"-> {ratio:.2f}x fewer on pool-resident round buffers")
    assert meth_b < free_b, (
        "pool-resident method collectives must copy fewer bytes than "
        "the free-function path")
    return [["measured", "collective", "cmpi_allreduce_free", procs,
             nbytes, "", f"{free_b:.0f}"],
            ["measured", "collective", "cmpi_allreduce_comm", procs,
             nbytes, "", f"{meth_b:.0f}"]]


def run_crossover_probe(procs: int = 2) -> None:
    """Exercise ``eager_threshold='auto'``: every rank runs the one-shot
    init-time micro-probe and reports its measured crossover."""
    from repro.core.runtime import run_processes

    def prog(env):
        env.comm.send(1 - env.rank, b"x" * 100_000, tag=1)
        data, _ = env.comm.recv(1 - env.rank, tag=1)
        assert len(data) == 100_000
        return env.comm.eager_threshold, env.comm.probed_crossover

    res = run_processes(procs, prog, pool_bytes=64 << 20,
                        eager_threshold="auto", timeout=300)
    for r, (thr, cross) in enumerate(res):
        print(f"rank {r}: auto eager_threshold={thr}B "
              f"(measured rendezvous crossover: "
              f"{cross if cross is not None else 'beyond probe range'})")


def run(quick: bool = False) -> list[list]:
    rows = run_modeled()
    sizes = [8, 512, 4 * KB, 64 * KB] if quick else \
        [8, 64, 512, 4 * KB, 16 * KB, 64 * KB, 256 * KB]
    iters = 30 if quick else 150
    shm_lat = shm_pingpong(sizes, iters=iters)
    tcp_lat = tcp_pingpong(sizes, iters=iters)
    rma_lat = run_measured_rma(sizes, iters=iters)
    shm_bw = shm_bandwidth(sizes, iters=max(iters // 10, 5))
    for s in sizes:
        rows.append(["measured", "twosided", "host_shm_cmpi", 2, s,
                     f"{shm_lat[s] * 1e6:.2f}",
                     f"{shm_bw[s] / MiB:.0f}"])
        rows.append(["measured", "onesided", "host_shm_rma", 2, s,
                     f"{rma_lat[s] * 1e6:.2f}", ""])
        rows.append(["measured", "twosided", "host_tcp_localhost", 2, s,
                     f"{tcp_lat[s] * 1e6:.2f}", ""])
    proto_sizes = [64 * KB, 1 * MiB] if quick else \
        [16 * KB, 64 * KB, 256 * KB, 1 * MiB]
    rows += run_protocols(proto_sizes, iters=20 if quick else 60)
    if not quick:
        # quick mode skips this: CI runs it via --smoke in the next step
        rows += run_collectives(iters=4)
    write_csv("fig5_8_osu",
              ["kind", "sided", "fabric", "procs", "msg_bytes",
               "latency_us", "bandwidth_MiB_s_or_copied_B"], rows)
    return rows


def main(quick: bool = False) -> None:
    rows = run(quick)
    # headline summary
    import collections
    d = {(r[0], r[1], r[2], r[3], r[4]): r for r in rows}
    cxl16k = float(d[("modeled", "onesided", "cxl_shm", 16, 16 * KB)][6])
    eth16k = float(d[("modeled", "onesided", "tcp_ethernet", 16, 16 * KB)][6])
    print(f"modeled one-sided 16KB/16p: CXL {cxl16k:.0f} MiB/s vs "
          f"TCP-Eth {eth16k:.0f} -> {cxl16k / eth16k:.0f}x "
          f"(paper: up to 71.6x)")
    meas = [r for r in rows if r[0] == "measured"]
    print(f"{len(meas)} measured rows (see artifacts/bench/fig5_8_osu.csv)")


def smoke() -> None:
    """CI-sized subset: the auto-threshold crossover probe plus the
    pool-resident collective copied-bytes comparison."""
    run_crossover_probe()
    run_collectives(iters=2)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: crossover probe + collective copies")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        main(quick=args.quick)
