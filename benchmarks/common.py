"""Shared benchmark helpers: CSV output + real ping-pong transports.

Two kinds of numbers appear throughout:
  measured:  real executions on THIS host (shared-memory pool between
             processes vs. localhost TCP sockets) — CPython-level costs,
             honest but not CXL-calibrated;
  modeled:   the Table-1-calibrated analytical model (perfmodel/) — the
             paper-accurate reproduction path (the paper itself models
             anything beyond its 4-node platform).
Every CSV row is tagged with which one it is.
"""
from __future__ import annotations

import csv
import os
import socket
import time
from multiprocessing import Process, get_context
from pathlib import Path

import numpy as np

ART = Path(__file__).resolve().parent.parent / "artifacts" / "bench"


def write_csv(name: str, header: list[str], rows: list[list]) -> Path:
    ART.mkdir(parents=True, exist_ok=True)
    p = ART / f"{name}.csv"
    with open(p, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return p


# --------------------------------------------------------------------------
# real SHM ping-pong (cMPI transport between two processes)
# --------------------------------------------------------------------------

def shm_pingpong(sizes: list[int], iters: int = 200,
                 cell_size: int = 65536) -> dict[int, float]:
    """Half-round-trip latency (s) per message size over the cMPI SPSC
    queue matrix in real shared memory, two processes."""
    from repro.core.runtime import run_processes

    def prog(env):
        out = {}
        payloads = {s: bytes(s) for s in sizes}
        for s in sizes:
            env.comm.barrier()
            t0 = time.perf_counter()
            for i in range(iters):
                if env.rank == 0:
                    env.comm.send(1, payloads[s], tag=1)
                    env.comm.recv(1, tag=2)
                else:
                    env.comm.recv(0, tag=1)
                    env.comm.send(0, payloads[s], tag=2)
            dt = time.perf_counter() - t0
            out[s] = dt / iters / 2.0
        return out

    res = run_processes(2, prog, pool_bytes=max(64 << 20,
                                                8 * cell_size * 64),
                        cell_size=cell_size, n_cells=16)
    return res[0]


def shm_bandwidth(sizes: list[int], iters: int = 50,
                  cell_size: int = 65536, window: int = 16
                  ) -> dict[int, float]:
    """Streaming bandwidth (B/s): rank 0 isends `window` messages, rank 1
    drains, then one ack — OMB bw pattern over real shared memory."""
    from repro.core.runtime import run_processes

    def prog(env):
        out = {}
        for s in sizes:
            payload = bytes(s)
            env.comm.barrier()
            t0 = time.perf_counter()
            for _ in range(iters):
                if env.rank == 0:
                    reqs = [env.comm.isend(1, payload, tag=3)
                            for _ in range(window)]
                    env.comm.waitall(reqs, timeout=120)
                    env.comm.recv(1, tag=4)
                else:
                    for _ in range(window):
                        env.comm.recv(0, tag=3, timeout=120)
                    env.comm.send(0, b"", tag=4)
            dt = time.perf_counter() - t0
            out[s] = iters * window * s / dt
        return out

    res = run_processes(2, prog, pool_bytes=max(128 << 20,
                                                8 * cell_size * 64),
                        cell_size=cell_size, n_cells=32, timeout=600)
    return res[0]


# --------------------------------------------------------------------------
# real TCP ping-pong (localhost sockets — the network-stack baseline)
# --------------------------------------------------------------------------

def _tcp_server(port: int, sizes, iters, q):
    srv = socket.socket()
    srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    srv.bind(("127.0.0.1", port))
    srv.listen(1)
    q.put("ready")
    conn, _ = srv.accept()
    conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    for s in sizes:
        buf = bytearray(s)
        for _ in range(iters):
            view = memoryview(buf)
            got = 0
            while got < s:
                got += conn.recv_into(view[got:])
            conn.sendall(buf)
    conn.close()
    srv.close()


def tcp_pingpong(sizes: list[int], iters: int = 200,
                 port: int = 51733) -> dict[int, float]:
    ctx = get_context("fork")
    q = ctx.Queue()
    p = ctx.Process(target=_tcp_server, args=(port, sizes, iters, q),
                    daemon=True)
    p.start()
    q.get(timeout=10)
    cli = socket.socket()
    cli.connect(("127.0.0.1", port))
    cli.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    out = {}
    for s in sizes:
        buf = bytes(s)
        rbuf = bytearray(s)
        t0 = time.perf_counter()
        for _ in range(iters):
            cli.sendall(buf)
            view = memoryview(rbuf)
            got = 0
            while got < s:
                got += cli.recv_into(view[got:])
        out[s] = (time.perf_counter() - t0) / iters / 2.0
    cli.close()
    p.join(timeout=10)
    return out
