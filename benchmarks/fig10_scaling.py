"""Fig 10: strong scaling of CG and miniAMR on the event simulator
(the paper's SimGrid study), CXL SHM vs TCP-CX6 vs TCP-Ethernet,
8 processes per node."""
from __future__ import annotations

from benchmarks.common import write_csv
from repro.perfmodel.apps import cg_program, miniamr_program
from repro.perfmodel.interconnects import (CXL_SHM, ETHERNET_TCP,
                                           MELLANOX_TCP)
from repro.perfmodel.simulator import Engine

FABRICS = {"cxl_shm": CXL_SHM, "tcp_cx6dx": MELLANOX_TCP,
           "tcp_ethernet": ETHERNET_TCP}


def run(quick: bool = False) -> list[list]:
    rows = []
    nodes_list = [2, 4, 8] if quick else [2, 4, 8, 16, 32]
    apps = {
        "cg": (cg_program, {"iters": 10 if quick else 20}),
        "miniamr": (miniamr_program, {"steps": 10 if quick else 20}),
    }
    for app, (maker, kw) in apps.items():
        for nodes in nodes_list:
            n = nodes * 8
            for fname, ic in FABRICS.items():
                res = Engine(n, ic, procs_per_node=8).run(
                    lambda r: maker(r, n, **kw))
                rows.append([app, nodes, fname,
                             f"{res['total_s']:.4f}",
                             f"{res['comm_s']:.4f}",
                             f"{res['comm_fraction'] * 100:.1f}"])
    write_csv("fig10_scaling",
              ["app", "nodes", "fabric", "total_s", "comm_s",
               "comm_pct"], rows)
    return rows


def main(quick: bool = False) -> None:
    rows = run(quick)
    by = {(r[0], r[1], r[2]): float(r[3]) for r in rows}
    nodes = sorted({r[1] for r in rows})
    for app in ("cg", "miniamr"):
        for n in nodes:
            c = by[(app, n, "cxl_shm")]
            m = by[(app, n, "tcp_cx6dx")]
            e = by[(app, n, "tcp_ethernet")]
            print(f"{app:8s} {n:3d} nodes: cxl {c:.3f}s cx6 {m:.3f}s "
                  f"eth {e:.3f}s | cxl speedup vs cx6 "
                  f"{(m / c - 1) * 100:5.1f}% | eth"
                  f"{'<' if e < m else '>'}cx6")


if __name__ == "__main__":
    main()
