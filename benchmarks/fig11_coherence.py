"""Fig 11: memset latency under uncacheable vs cached+clflush vs
cached+clflushopt coherence.

modeled   : the calibrated Fig-11 curves (64 B .. 128 KB).
executable: the SAME protocol run on the incoherent-pool cache model —
            event counts (lines flushed, fences, uncached ops) converted
            to time by perfmodel.protocol_time. This ties the executable
            coherence layer to the analytical model.
"""
from __future__ import annotations

from benchmarks.common import write_csv
from repro.core.coherence import CoherentView
from repro.core.pool import IncoherentPool, LocalPool, RankCache
from repro.perfmodel.interconnects import coherence_latency, protocol_time

SIZES = [64, 256, 1024, 2048, 8192, 32768, 131072]


def run(quick: bool = False) -> list[list]:
    rows = []
    for s in SIZES:
        for mode in ("uncacheable", "clflush", "clflushopt"):
            rows.append(["modeled", mode, s,
                         f"{coherence_latency(s, mode) * 1e6:.1f}"])
    # executable protocol: write `s` bytes through each mode's view
    for s in SIZES:
        for mode, mname in (("incoherent", "exec_clflushopt"),
                            ("uncacheable", "exec_uncacheable")):
            backing = LocalPool(2 * 131072 + 4096)
            pool = IncoherentPool(backing, RankCache(backing)) \
                if mode == "incoherent" else backing
            view = CoherentView(pool, mode)
            view.write_release(0, bytes(s))
            t = protocol_time(view.stats,
                              mode="clflushopt" if mode == "incoherent"
                              else "uncacheable")
            rows.append(["executable", mname, s, f"{t * 1e6:.1f}"])
    write_csv("fig11_coherence", ["kind", "mode", "bytes", "latency_us"],
              rows)
    return rows


def main(quick: bool = False) -> None:
    rows = run(quick)
    d = {(r[0], r[1], r[2]): float(r[3]) for r in rows}
    r2k = d[("modeled", "uncacheable", 2048)] / d[("modeled", "clflush",
                                                   2048)]
    print(f"uncacheable/clflush at 2KB: {r2k:.0f}x (paper: ~256x)")
    r128k = d[("modeled", "clflush", 131072)] / d[("modeled", "clflushopt",
                                                   131072)]
    print(f"clflush/clflushopt at 128KB: {r128k:.1f}x (paper: up to 4x)")


if __name__ == "__main__":
    main()
