"""Table 1: memory-access latency and bandwidth across interconnects.

Rows 'modeled' are the Table-1-calibrated constants (the reproduction).
Rows 'measured' are real on this host, at two levels:
  * fabric level (what Table 1 compares): RAW shared-memory load/store
    latency + memcpy bandwidth vs. the TCP stack round trip — the
    memory-fabric-vs-network-stack gap the paper builds on;
  * MPI level: the cMPI transport between two real processes. NOTE: on a
    single-core CPython host the per-op interpreter cost (~tens of us)
    dominates, so this row demonstrates FUNCTIONALITY, not the hardware
    ratio — the quantitative claims ride the calibrated model, exactly as
    the paper rides SimGrid beyond its 4-node platform.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import shm_pingpong, tcp_pingpong, write_csv
from repro.perfmodel.interconnects import INTERCONNECTS


def raw_shm_latency(iters: int = 20000) -> float:
    """Raw 8B store+load against a real shared-memory segment."""
    from repro.core.pool import SharedMemoryPool
    pool = SharedMemoryPool(1 << 20, create=True)
    try:
        buf = pool.shm.buf
        word = b"\x07" * 8
        t0 = time.perf_counter()
        for i in range(iters):
            off = (i % 1024) * 64
            buf[off:off + 8] = word
            _ = bytes(buf[off:off + 8])
        return (time.perf_counter() - t0) / iters
    finally:
        pool.close()
        pool.unlink()


def raw_shm_bandwidth(nbytes: int = 64 << 20) -> float:
    from repro.core.pool import SharedMemoryPool
    pool = SharedMemoryPool(nbytes, create=True)
    try:
        src = np.ones(nbytes // 8, np.float64)
        dst = np.frombuffer(pool.shm.buf, np.float64)
        t0 = time.perf_counter()
        dst[:] = src
        return nbytes / (time.perf_counter() - t0)
    finally:
        del dst
        pool.close()
        pool.unlink()


def run(quick: bool = False) -> list[list]:
    rows = []
    for name, ic in INTERCONNECTS.items():
        rows.append(["modeled", name, f"{ic.raw_latency(8) * 1e9:.0f}",
                     f"{ic.bandwidth / 2**30:.1f}"])
    iters = 50 if quick else 300
    raw_lat = raw_shm_latency(2000 if quick else 20000)
    raw_bw = raw_shm_bandwidth(16 << 20 if quick else 64 << 20)
    shm = shm_pingpong([8], iters=iters)
    tcp = tcp_pingpong([8], iters=iters)
    rows.append(["measured-fabric", "host_shm_raw(8B)",
                 f"{raw_lat * 1e9:.0f}", f"{raw_bw / 2**30:.1f}"])
    rows.append(["measured-fabric", "host_tcp_stack(8B RTT/2)",
                 f"{tcp[8] * 1e9:.0f}", ""])
    rows.append(["measured-fabric", "shm_vs_tcp_stack_ratio",
                 f"{tcp[8] / raw_lat:.1f}", ""])
    rows.append(["measured-mpi", "host_shm_cmpi(8B)",
                 f"{shm[8] * 1e9:.0f}",
                 "CPython per-op cost dominates; functionality demo"])
    write_csv("table1", ["kind", "interconnect", "latency_ns", "bw_GiB_s"],
              rows)
    return rows


def main(quick: bool = False) -> None:
    for r in run(quick):
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
