"""Fig 9: two-sided bandwidth vs message size for varying message-CELL
sizes (16/32/64/128 KB).

measured: the real cMPI SPSC queues between two processes — the mechanism
          (messages larger than a cell are chunked; bigger cells amortize
          per-cell overhead until a plateau) is what the paper tunes.
modeled : per-cell overhead model at CXL constants showing the paper's
          threshold: default 16 KB caps bandwidth, 64 KB lifts the peak,
          beyond 64 KB no further gain.
kernel  : the TPU reading — the cellcopy Pallas kernel's block-shape sweep
          (cells-per-VMEM-block), CPU-interpret wall time (relative).
"""
from __future__ import annotations

import time

from benchmarks.common import shm_bandwidth, write_csv
from repro.perfmodel.interconnects import CXL_SHM

KB = 1024
MiB = 1024 * 1024
CELLS = [16 * KB, 32 * KB, 64 * KB, 128 * KB]
MSGS = [4 * KB, 16 * KB, 64 * KB, 256 * KB, 1024 * KB]

T_CELL = 2.2e-6          # per-cell enqueue overhead (coherence epilogue)
_CELL_HALF = 24 * KB     # cell size at which the queue pipeline reaches
#                          half of fabric peak (calibrated to Fig 9)
_TWOSIDED_CEIL = 6.33e9  # ~6,050 MiB/s: the double-copy ceiling (paper)


def modeled_bw(msg: int, cell: int, procs: int = 32) -> float:
    """Chunked-transfer model: per message ceil(msg/cell) cells, each
    paying T_CELL + copy; small cells additionally throttle the queue
    pipeline (more head/tail round trips per byte), which is what makes
    the 16 KB default cap bandwidth and 64 KB lift it (Fig 9)."""
    n_cells = -(-msg // cell)
    t = n_cells * T_CELL + msg / CXL_SHM.bandwidth \
        * CXL_SHM._contention(msg, procs)
    agg = procs * msg / t * 0.70          # two-sided double-copy factor
    pipeline_cap = (CXL_SHM.fabric_peak * 1.073  # GiB->GB constant
                    * cell / (cell + _CELL_HALF))
    return min(agg, pipeline_cap, _TWOSIDED_CEIL)


def run(quick: bool = False) -> list[list]:
    rows = []
    for cell in CELLS:
        for msg in MSGS:
            rows.append(["modeled", cell // KB, msg // KB,
                         f"{modeled_bw(msg, cell) / MiB:.0f}"])
    # measured: real SPSC queues, cell size swept
    msizes = [16 * KB, 256 * KB] if quick else [16 * KB, 64 * KB, 256 * KB]
    iters = 4 if quick else 12
    for cell in ([16 * KB, 64 * KB] if quick else CELLS):
        bw = shm_bandwidth(msizes, iters=iters, cell_size=cell, window=8)
        for msg in msizes:
            rows.append(["measured", cell // KB, msg // KB,
                         f"{bw[msg] / MiB:.0f}"])
    # kernel block sweep (TPU cell == VMEM block)
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels.cellcopy.kernel import cellcopy
    src = jnp.asarray(np.arange(64 * 2048, dtype=np.int32)
                      .reshape(64, 2048))
    for bc in (1, 4, 16, 64):
        f = lambda: cellcopy(src, block_cells=bc)[0].block_until_ready()
        f()
        t0 = time.perf_counter()
        for _ in range(3):
            f()
        dt = (time.perf_counter() - t0) / 3
        rows.append(["kernel_interp", bc * 8, 512, f"{dt * 1e3:.1f}ms"])
    write_csv("fig9_cellsize",
              ["kind", "cell_KB|block", "msg_KB", "bw_MiB_s|time"], rows)
    return rows


def main(quick: bool = False) -> None:
    rows = run(quick)
    best = {}
    for r in rows:
        if r[0] == "modeled":
            best.setdefault(r[1], 0)
            best[r[1]] = max(best[r[1]], float(r[3]))
    print("modeled peak two-sided bw by cell size:",
          {f"{k}KB": f"{v:.0f}MiB/s" for k, v in best.items()},
          "(paper: 16KB -> ~3600, 64KB -> ~6000, no gain beyond)")


if __name__ == "__main__":
    main()
