"""Serve-tier QPS/latency benchmark with a copy-accounting gate.

Drives the ``repro.serve`` data plane — one router rank admitting an
open-loop Poisson session population through persistent-request pools,
worker ranks running continuous-batching decode over the rank-sharded
dynamic-window page cache — and records per-session latency (p50/p99),
sustained QPS and the exact per-rank copy accounting.

Two kinds of gate, same split as ``fig5_8_osu``:

  * the COPY gate is deterministic and always enforced: every worker's
    ``rma_put``/``rma_get`` buckets must equal its reported page bytes
    plus 8 B per ``raccumulate`` EXACTLY, nothing may land in
    ``rndv_staged``/``rndv_posted``, and the router (a pure control
    rank) must show no RMA buckets at all — pages move one-sidedly
    with zero receiver-side drain, or this fails loudly;
  * the p99 SLO gate is wall-clock and budget-overridable
    (``quality_gates.serve_p99_us_max@smoke`` in
    ``artifacts/bench/budget_copies.json``), waived with the standard
    loud warning on sandboxed kernels where a cooperative yield costs
    100x its real-kernel price. The measurement is recorded either way.

The smoke cut (CI) serves a few dozen sessions on 3 ranks; the full
cut (nightly) serves thousands on 4 ranks with sampled router-side
checksum verification.  Results MERGE into
``artifacts/bench/smoke_copies.json`` under the ``"serve"`` key
(``fig5_8_osu`` rewrites that file wholesale, so this benchmark must
run after it — the CI step order does).

  PYTHONPATH=src python -m benchmarks.serve_qps --smoke
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from benchmarks.common import ART, write_csv                   # noqa: E402
from benchmarks.fig5_8_osu import (SANDBOX_YIELD_US,           # noqa: E402
                                   yield_cost_us)
from repro.serve import ServeConfig, run_serve                 # noqa: E402

BUDGET_PATH = ART / "budget_copies.json"
SMOKE_PATH = ART / "smoke_copies.json"

# write-budget default / fallback when the checked-in budget carries no
# serve gate: generous enough for a loaded CI runner, tight enough to
# catch a data plane that started staging pages through copies
SERVE_P99_MAX_US = 250_000.0

SMOKE = dict(ranks=3, sessions=40, rate=400.0, verify_every=1)
FULL = dict(ranks=4, sessions=2000, rate=1500.0, verify_every=29)


def check_copy_accounting(reports: list[dict]) -> list[str]:
    """The zero-receiver-drain contract, exact to the byte."""
    problems = []
    router, workers = reports[0], reports[1:]
    rd = router["stats_delta"]["path_copied_bytes"]
    for path in ("rma_put", "rma_get", "rndv_staged", "rndv_posted"):
        if rd.get(path, 0):
            problems.append(
                f"router counted {rd[path]} B under {path} — the "
                f"control rank must never touch page payloads")
    for w in workers:
        d = w["stats_delta"]["path_copied_bytes"]
        racc = 8 * w["racc_calls"]
        want_put = w["rput_bytes"] + racc
        want_get = w["rget_bytes"] + racc
        if d.get("rma_put", 0) != want_put:
            problems.append(
                f"worker {w['rank']}: rma_put {d.get('rma_put', 0)} B "
                f"!= {want_put} B (page fills {w['rput_bytes']} + "
                f"raccumulate {racc})")
        if d.get("rma_get", 0) != want_get:
            problems.append(
                f"worker {w['rank']}: rma_get {d.get('rma_get', 0)} B "
                f"!= {want_get} B (page drains {w['rget_bytes']} + "
                f"raccumulate {racc})")
        for path in ("rndv_staged", "rndv_posted"):
            if d.get(path, 0):
                problems.append(
                    f"worker {w['rank']}: {d[path]} B under {path} — "
                    f"a page went through a copy path")
    return problems


def run_bench(smoke: bool, seed: int = 0, ranks: int | None = None,
              sessions: int | None = None,
              rate: float | None = None) -> dict:
    params = dict(SMOKE if smoke else FULL)
    if ranks is not None:
        params["ranks"] = ranks
    if sessions is not None:
        params["sessions"] = sessions
    if rate is not None:
        params["rate"] = rate
    n_ranks = params.pop("ranks")
    cfg = ServeConfig(seed=seed, deadline_s=60.0 if smoke else 600.0,
                      slots_per_worker=64 if smoke else 128,
                      **params)
    reports = run_serve(cfg, ranks=n_ranks,
                        timeout=cfg.deadline_s + 60.0)
    router, workers = reports[0], reports[1:]

    rows = [["router", 0, router["sessions"], router["tokens"], 0, 0,
             round(router["p50_us"], 1), round(router["p99_us"], 1),
             round(router["qps"], 2)]]
    for w in workers:
        rows.append(["worker", w["rank"], w["served"], w["tokens"],
                     w["rput_bytes"], w["rget_bytes"], "", "", ""])
    write_csv("serve_qps",
              ["role", "rank", "sessions", "tokens", "rput_bytes",
               "rget_bytes", "p50_us", "p99_us", "qps"], rows)

    problems = []
    if router["bad_checksums"]:
        problems.append(f"{router['bad_checksums']} router-side "
                        f"checksum mismatches")
    bad_verify = sum(w["verify_failures"] for w in workers)
    if bad_verify:
        problems.append(f"{bad_verify} worker page-drain verify "
                        f"failures")
    if router["stats_tokens"] != router["tokens"]:
        problems.append(
            f"raccumulate'd token total {router['stats_tokens']} != "
            f"{router['tokens']} reported by DONE frames — the "
            f"request-based accumulate lost an update")
    problems += check_copy_accounting(reports)
    return dict(cfg=params, ranks=n_ranks, router=router,
                workers=workers, problems=problems)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI cut: few sessions, full verification")
    ap.add_argument("--ranks", type=int, default=None)
    ap.add_argument("--sessions", type=int, default=None)
    ap.add_argument("--rate", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    out = run_bench(args.smoke, seed=args.seed, ranks=args.ranks,
                    sessions=args.sessions, rate=args.rate)
    router = out["router"]
    print(f"serve_qps: {router['sessions']} sessions on "
          f"{out['ranks']} ranks — qps {router['qps']:.1f}, "
          f"p50 {router['p50_us']:.0f} us, "
          f"p99 {router['p99_us']:.0f} us, "
          f"tokens {router['tokens']}")
    for w in out["workers"]:
        print(f"  worker {w['rank']}: served {w['served']}, "
              f"rput {w['rput_bytes']} B, rget {w['rget_bytes']} B, "
              f"raccumulate x{w['racc_calls']}")

    yc = yield_cost_us()
    record = dict(
        ranks=out["ranks"], sessions=router["sessions"],
        qps=round(router["qps"], 2),
        p50_us=round(router["p50_us"], 1),
        p99_us=round(router["p99_us"], 1),
        mean_us=round(router["mean_us"], 1),
        tokens=router["tokens"],
        workers=[{k: w[k] for k in
                  ("rank", "served", "tokens", "rput_bytes",
                   "rget_bytes", "racc_calls")} for w in out["workers"]],
        host_yield_cost_us=round(yc, 2))
    # merge, don't overwrite: fig5_8_osu owns the rest of this file
    ART.mkdir(parents=True, exist_ok=True)
    merged = {}
    if SMOKE_PATH.exists():
        merged = json.loads(SMOKE_PATH.read_text())
    merged["serve"] = record
    SMOKE_PATH.write_text(json.dumps(merged, indent=2) + "\n")
    print(f"serve profile merged into {SMOKE_PATH}")

    # deterministic gates: correctness + exact copy accounting
    if out["problems"]:
        for p in out["problems"]:
            print(f"FAIL: {p}")
        return 1
    print("copy accounting exact: pages moved one-sidedly, zero "
          "receiver-side drain")

    # the p99 SLO gate: budget-overridable, sandbox-waived
    p99_max = SERVE_P99_MAX_US
    if BUDGET_PATH.exists():
        qg = json.loads(BUDGET_PATH.read_text()).get("quality_gates", {})
        p99_max = qg.get("serve_p99_us_max@smoke", p99_max)
    if yc > SANDBOX_YIELD_US:
        print(f"WARNING: sandboxed kernel detected (sched-yield "
              f"{yc:.0f} us > {SANDBOX_YIELD_US:.0f} us) — serve p99 "
              f"SLO gate ({p99_max:.0f} us) waived on this host; "
              f"measured {router['p99_us']:.0f} us")
    elif args.smoke and router["p99_us"] > p99_max:
        print(f"FAIL: serve p99 {router['p99_us']:.0f} us > "
              f"{p99_max:.0f} us SLO "
              f"(quality_gates.serve_p99_us_max@smoke)")
        return 1
    else:
        print(f"serve p99 {router['p99_us']:.0f} us <= "
              f"{p99_max:.0f} us SLO")
    return 0


if __name__ == "__main__":
    sys.exit(main())
